//! TESLA / dflow-galaxy (paper §3.6, Fig. 8): the
//! Train → Explore → Screen → Label concurrent-learning loop, the same
//! shape as DP-GEN/DP-GEN2.
//!
//! Per iteration:
//! 1. **Train** — 4 NN-potential ensemble members, sliced in parallel
//!    (`train-<iter>-<member>` keys make every member reusable on restart);
//! 2. **Explore** — MD walkers fan out from fresh starting configurations
//!    (sliced `md_explore`, trajectories stacked);
//! 3. **Screen** — ensemble force deviation per candidate, then trust-
//!    interval selection;
//! 4. **Label** — the selected configurations get reference energies/forces
//!    (`lj_ef` = the DFT surrogate) and are merged into the dataset.
//!
//! The loop recurses (a steps template instantiating itself) while the max
//! model deviation stays above the convergence threshold and the iteration
//! budget remains — Dflow's "dynamic loop via recursion + breaking
//! condition" (§2.2).

use crate::core::{
    ArtSrc, CmpOp, ContainerTemplate, Expr, Operand, ParamSrc, ParamType, Signature, Slices,
    Step, StepPolicy, Steps, Value, Workflow,
};
use crate::science::ops;

/// Tunables for the loop.
#[derive(Debug, Clone)]
pub struct TeslaConfig {
    /// Ensemble size (paper default 4).
    pub n_models: usize,
    /// MD walkers per iteration.
    pub n_walkers: usize,
    /// `md_step` calls per walker (each = 20 substeps).
    pub md_calls: usize,
    /// Adam steps per training task.
    pub train_steps: usize,
    /// Trust interval for selection.
    pub devi_lo: f64,
    pub devi_hi: f64,
    /// Convergence: stop when max deviation < this.
    pub conv_devi: f64,
    /// Iteration budget.
    pub max_iters: usize,
    /// Initial labeled configurations.
    pub init_configs: usize,
    /// Retries on transient failures.
    pub retries: u32,
}

impl Default for TeslaConfig {
    fn default() -> Self {
        TeslaConfig {
            n_models: 4,
            n_walkers: 6,
            md_calls: 5,
            train_steps: 120,
            devi_lo: 0.05,
            devi_hi: 5.0,
            conv_devi: 0.30,
            max_iters: 3,
            init_configs: 8,
            retries: 2,
        }
    }
}

/// Build the TESLA workflow. Per-iteration observables are reachable after
/// the run through keyed steps: `screen-<iter>` (params `max_devi`,
/// `n_selected`... on `select`), `train-<iter>-<member>` (`final_loss`).
pub fn workflow(cfg: &TeslaConfig, seed: i64) -> Workflow {
    let mut retry = StepPolicy::default();
    retry.retries = cfg.retries;

    let wf = Workflow::new("tesla")
        .container(ContainerTemplate::new("gen-configs", ops::gen_configs_op()))
        .container(ContainerTemplate::new("label", ops::label_op()).image("deepmd/dft:1"))
        .container(
            ContainerTemplate::new("train", ops::train_op())
                .image("deepmd/train:1")
                .resources(crate::cluster::Resources::new(2000, 4000, 1))
                .select_node("accel", "gpu"),
        )
        .container(
            ContainerTemplate::new("explore", ops::md_explore_op())
                .image("deepmd/lammps:1")
                .resources(crate::cluster::Resources::new(2000, 2000, 1))
                .select_node("accel", "gpu"),
        )
        .container(ContainerTemplate::new("collect", ops::collect_trajectories_op()))
        .container(ContainerTemplate::new("model-devi", ops::model_devi_op()))
        .container(ContainerTemplate::new("select", ops::select_op()))
        .container(ContainerTemplate::new("merge", crate::apps::merge2_op()))
        .container(ContainerTemplate::new("inc", crate::apps::inc_op()));

    // train needs a per-iteration tag for reuse keys: member is {{item}} and
    // the iteration arrives via the template input "tag" rendered into the key
    let iter_steps = Steps::new("tesla-iter")
        .signature(
            Signature::new()
                .in_param("iter", ParamType::Int)
                .in_param("max_iters", ParamType::Int)
                .in_param("conv_devi", ParamType::Float)
                .in_artifact("dataset"),
        )
        // 1. TRAIN: ensemble members in parallel slices
        .then(
            Step::new("train", "train")
                .param("steps", cfg.train_steps as i64)
                .param("member", crate::apps::index_list(cfg.n_models))
                .param("tag", ParamSrc::Input("iter".into()))
                .artifact("dataset", ArtSrc::Input("dataset".into()))
                .slices(
                    Slices::over("member")
                        .stack("final_loss")
                        .stack_artifact("params")
                        .parallelism(cfg.n_models),
                )
                .key("train-{{inputs.parameters.tag}}-{{item}}")
                .policy(retry.clone()),
        )
        // 2. EXPLORE: fresh walkers seeded by the iteration
        .then(
            Step::new("gen-walkers", "gen-configs")
                .param("count", cfg.n_walkers as i64)
                .param_from_input("seed", "iter")
                .param("jitter", 0.10f64),
        )
        .then(
            Step::new("explore", "explore")
                .param("n_calls", cfg.md_calls as i64)
                .param("seed", crate::apps::index_list(cfg.n_walkers))
                .param("temp", 0.3f64)
                .param("tag", ParamSrc::Input("iter".into()))
                .artifact(
                    "config",
                    ArtSrc::StepOutput { step: "gen-walkers".into(), name: "configs".into() },
                )
                .slices(
                    Slices::over("seed")
                        .artifact("config")
                        .stack("final_pe")
                        .stack_artifact("trajectory")
                        .parallelism(cfg.n_walkers),
                )
                .key("explore-{{inputs.parameters.tag}}-{{item}}")
                .policy(retry.clone()),
        )
        .then(Step::new("collect", "collect").artifact(
            "trajectories",
            ArtSrc::StepOutput { step: "explore".into(), name: "trajectory".into() },
        ))
        // 3. SCREEN: ensemble deviation + trust-interval selection
        .then(
            Step::new("devi", "model-devi")
                .artifact(
                    "params",
                    ArtSrc::StepOutput { step: "train".into(), name: "params".into() },
                )
                .artifact(
                    "configs",
                    ArtSrc::StepOutput { step: "collect".into(), name: "configs".into() },
                ),
        )
        .then(
            Step::new("select", "select")
                .param_from_step("max_devis", "devi", "max_devis")
                .param("lo", cfg.devi_lo)
                .param("hi", cfg.devi_hi)
                .param("cap", 16i64)
                .param("tag", ParamSrc::Input("iter".into()))
                .artifact(
                    "configs",
                    ArtSrc::StepOutput { step: "collect".into(), name: "configs".into() },
                )
                .key("screen-{{inputs.parameters.tag}}"),
        )
        // 4. LABEL new data and merge into the dataset
        .then(
            Step::new("label", "label")
                .artifact(
                    "configs",
                    ArtSrc::StepOutput { step: "select".into(), name: "selected".into() },
                )
                .policy(retry.clone())
                // only label when something was selected
                .when(Expr::gt(
                    Operand::StepOutput { step: "select".into(), name: "n_selected".into() },
                    Operand::Const(Value::Int(0)),
                )),
        )
        .then(
            Step::new("merge", "merge")
                .artifact("base", ArtSrc::Input("dataset".into()))
                .artifact(
                    "update",
                    ArtSrc::StepOutput { step: "label".into(), name: "dataset".into() },
                )
                .when(Expr::gt(
                    Operand::StepOutput { step: "select".into(), name: "n_selected".into() },
                    Operand::Const(Value::Int(0)),
                )),
        )
        .then(Step::new("bump", "inc").param_from_input("i", "iter"))
        // 5. RECURSE while not converged and under budget and new data came in
        .then(
            Step::new("again", "tesla-iter")
                .param_from_step("iter", "bump", "next")
                .param_from_input("max_iters", "max_iters")
                .param_from_input("conv_devi", "conv_devi")
                .artifact(
                    "dataset",
                    ArtSrc::StepOutput { step: "merge".into(), name: "dataset".into() },
                )
                .when(Expr::And(
                    Box::new(Expr::And(
                        Box::new(Expr::Cmp {
                            lhs: Operand::StepOutput {
                                step: "select".into(),
                                name: "max_devi".into(),
                            },
                            op: CmpOp::Ge,
                            rhs: Operand::Input("conv_devi".into()),
                        }),
                        Box::new(Expr::Cmp {
                            lhs: Operand::StepOutput {
                                step: "bump".into(),
                                name: "next".into(),
                            },
                            op: CmpOp::Lt,
                            rhs: Operand::Input("max_iters".into()),
                        }),
                    )),
                    Box::new(Expr::gt(
                        Operand::StepOutput { step: "select".into(), name: "n_selected".into() },
                        Operand::Const(Value::Int(0)),
                    )),
                )),
        );

    // bootstrap: initial configurations + labels, then iteration 0
    let main = Steps::new("main")
        .then(
            Step::new("init-configs", "gen-configs")
                .param("count", cfg.init_configs as i64)
                .param("seed", seed)
                .param("jitter", 0.06f64),
        )
        .then(
            Step::new("init-label", "label")
                .artifact(
                    "configs",
                    ArtSrc::StepOutput { step: "init-configs".into(), name: "configs".into() },
                )
                .policy(retry)
                .key("init-label"),
        )
        .then(
            Step::new("loop", "tesla-iter")
                .param("iter", 0i64)
                .param("max_iters", cfg.max_iters as i64)
                .param("conv_devi", cfg.conv_devi)
                .artifact(
                    "dataset",
                    ArtSrc::StepOutput { step: "init-label".into(), name: "dataset".into() },
                ),
        );

    wf.steps(iter_steps).steps(main).entrypoint("main")
}

/// One iteration's observables, extracted from keyed steps after a run.
#[derive(Debug, Clone)]
pub struct IterStats {
    pub iter: usize,
    /// Mean final training loss over the ensemble.
    pub mean_loss: f64,
    /// Max model deviation over explored candidates.
    pub max_devi: f64,
    /// Candidates selected for labeling.
    pub n_selected: i64,
}

/// Extract the per-iteration convergence trace from a finished run via
/// keyed steps (`train-<iter>-<member>`, `screen-<iter>`) — exactly the
/// paper's `query_step` pattern (§2.5).
pub fn convergence_trace(run: &crate::engine::WorkflowRun, cfg: &TeslaConfig) -> Vec<IterStats> {
    let mut out = Vec::new();
    for iter in 0..cfg.max_iters {
        let mut losses = Vec::new();
        for member in 0..cfg.n_models {
            if let Some(s) = run.query_step(&format!("train-{iter}-{member}")) {
                if let Some(l) = s.outputs.params.get("final_loss").and_then(Value::as_float) {
                    losses.push(l);
                }
            }
        }
        let Some(screen) = run.query_step(&format!("screen-{iter}")) else { break };
        if losses.is_empty() {
            break;
        }
        out.push(IterStats {
            iter,
            mean_loss: losses.iter().sum::<f64>() / losses.len() as f64,
            max_devi: screen
                .outputs
                .params
                .get("max_devi")
                .and_then(Value::as_float)
                .unwrap_or(f64::NAN),
            n_selected: screen
                .outputs
                .params
                .get("n_selected")
                .and_then(Value::as_int)
                .unwrap_or(0),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tesla_workflow_validates() {
        workflow(&TeslaConfig::default(), 42).validate().unwrap();
    }

    #[test]
    fn tesla_small_config_validates() {
        let cfg = TeslaConfig { n_models: 2, n_walkers: 2, max_iters: 1, ..Default::default() };
        workflow(&cfg, 1).validate().unwrap();
    }
}
