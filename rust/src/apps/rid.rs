//! Rid-kit (paper §3.3, Fig. 5): reinforced dynamics as a cyclic workflow.
//!
//! The core is the **Block** super-OP executed once per iteration:
//! Exploration (sliced biased MD on GPUs) → Selection (uncertainty) →
//! Labeling (restrained MD per conformation, default parallelism 10) →
//! Training (default 4 parallel tasks). Block updates the neural networks
//! and produces the next iteration's starting conformations — here the
//! networks are the NN-potential ensemble and "mean forces" come from the
//! LJ reference (the substitution table in DESIGN.md).

use crate::core::{
    ArtSrc, ContainerTemplate, ParamSrc, ParamType, Signature, Slices, Step, StepPolicy, Steps,
    Workflow,
};
use crate::science::ops;

/// Rid-kit knobs (paper defaults: labeling parallelism 10, training 4).
#[derive(Debug, Clone)]
pub struct RidConfig {
    /// Concurrent walkers in Exploration.
    pub n_walkers: usize,
    /// `md_step` calls per walker.
    pub md_calls: usize,
    /// Parallelism of the Labeling slices (paper default 10).
    pub label_parallelism: usize,
    /// Training tasks (paper default 4).
    pub n_train: usize,
    /// Adam steps per training task.
    pub train_steps: usize,
    /// Selection trust interval.
    pub devi_lo: f64,
    pub devi_hi: f64,
    /// Block iterations.
    pub iterations: usize,
}

impl Default for RidConfig {
    fn default() -> Self {
        RidConfig {
            n_walkers: 4,
            md_calls: 4,
            label_parallelism: 10,
            n_train: 4,
            train_steps: 80,
            devi_lo: 0.0,
            devi_hi: 10.0,
            iterations: 2,
        }
    }
}

/// The Block super-OP: one RiD iteration (Fig. 5).
///
/// Inputs: `iter` + the accumulated `dataset` and previous `models` (list
/// artifact). Outputs: updated `dataset` and `models`.
fn block_steps(cfg: &RidConfig) -> Steps {
    let mut retry = StepPolicy::default();
    retry.retries = 2;
    Steps::new("rid-block")
        .signature(
            Signature::new()
                .in_param("iter", ParamType::Int)
                .in_artifact("dataset")
                .in_artifact("dataset_models")
                .in_artifact("conformations")
                .out_param("n_labeled", ParamType::Int)
                .out_artifact("dataset")
                .out_artifact("models")
                .out_artifact("conformations"),
        )
        // 1. Exploration: biased MD on different GPUs concurrently (Slices)
        .then(
            Step::new("exploration", "rid-explore")
                .param("n_calls", cfg.md_calls as i64)
                .param("seed", crate::apps::index_list(cfg.n_walkers))
                .param("temp", 0.4f64)
                .param("tag", ParamSrc::Input("iter".into()))
                .artifact("config", ArtSrc::Input("conformations".into()))
                .slices(
                    Slices::over("seed")
                        .artifact("config")
                        .stack("final_pe")
                        .stack_artifact("trajectory")
                        .parallelism(cfg.n_walkers),
                )
                .key("explore-{{inputs.parameters.tag}}-{{item}}")
                .policy(retry.clone()),
        )
        .then(Step::new("gather", "rid-collect").artifact(
            "trajectories",
            ArtSrc::StepOutput { step: "exploration".into(), name: "trajectory".into() },
        ))
        // 2. Selection: cluster/uncertainty filter (cheap, 1-2 CPU cores)
        .then(
            Step::new("devi", "rid-devi")
                .artifact("params", ArtSrc::Input("dataset_models".into()))
                .artifact(
                    "configs",
                    ArtSrc::StepOutput { step: "gather".into(), name: "configs".into() },
                ),
        )
        .then(
            Step::new("selection", "rid-select")
                .param_from_step("max_devis", "devi", "max_devis")
                .param("lo", cfg.devi_lo)
                .param("hi", cfg.devi_hi)
                .param("cap", cfg.n_walkers as i64 * 4)
                .param("tag", ParamSrc::Input("iter".into()))
                .artifact(
                    "configs",
                    ArtSrc::StepOutput { step: "gather".into(), name: "configs".into() },
                )
                .key("select-{{inputs.parameters.tag}}"),
        )
        // 3. Labeling: restrained MD per conformation, parallelism 10
        .then(
            Step::new("labeling", "rid-label")
                .param("conf_id", crate::apps::index_list(cfg.n_walkers * 4))
                .artifact(
                    "config",
                    ArtSrc::StepOutput { step: "selection".into(), name: "selected".into() },
                )
                .slices(
                    Slices::over("conf_id")
                        .artifact("config")
                        .stack("energy")
                        .stack_artifact("labeled")
                        .parallelism(cfg.label_parallelism)
                        .continue_on(crate::core::ContinueOn::SuccessRatio(0.5)),
                )
                .policy(retry.clone()),
        )
        .then(Step::new("collect-labels", "rid-merge-list").artifact(
            "datasets",
            ArtSrc::StepOutput { step: "labeling".into(), name: "labeled".into() },
        ).artifact("base", ArtSrc::Input("dataset".into())))
        // 4. Training: 4 parallel tasks on GPUs (Slices)
        .then(
            Step::new("training", "rid-train")
                .param("steps", cfg.train_steps as i64)
                .param("member", crate::apps::index_list(cfg.n_train))
                .param("tag", ParamSrc::Input("iter".into()))
                .artifact(
                    "dataset",
                    ArtSrc::StepOutput { step: "collect-labels".into(), name: "dataset".into() },
                )
                .slices(
                    Slices::over("member")
                        .stack("final_loss")
                        .stack_artifact("params")
                        .parallelism(cfg.n_train),
                )
                .key("train-{{inputs.parameters.tag}}-{{item}}")
                .policy(retry),
        )
        .out_param_from("n_labeled", "collect-labels", "count")
        .out_artifact_from("dataset", "collect-labels", "dataset")
        .out_artifact_from("models", "training", "params")
        .out_artifact_from("conformations", "selection", "selected")
}

/// Full Rid-kit workflow: bootstrap conformations + labels, then
/// `iterations` Block executions chained serially (Fig. 5's cycle).
pub fn workflow(cfg: &RidConfig, seed: i64) -> Workflow {
    let wf = Workflow::new("rid-kit")
        .container(ContainerTemplate::new("rid-gen", ops::gen_configs_op()))
        .container(
            ContainerTemplate::new("rid-explore", ops::md_explore_op())
                .image("rid/gromacs:1")
                .resources(crate::cluster::Resources::new(2000, 2000, 1)),
        )
        .container(ContainerTemplate::new("rid-collect", ops::collect_trajectories_op()))
        .container(ContainerTemplate::new("rid-devi", ops::model_devi_op()))
        .container(
            ContainerTemplate::new("rid-select", ops::select_op())
                .resources(crate::cluster::Resources::cpu(1000)),
        )
        .container(
            ContainerTemplate::new("rid-label", ops::label_one_op())
                .image("rid/label:1")
                .resources(crate::cluster::Resources::cpu(2000)),
        )
        .container(ContainerTemplate::new("rid-merge-list", ops::merge_datasets_op()))
        .container(
            ContainerTemplate::new("rid-train", ops::train_op())
                .image("rid/train:1")
                .resources(crate::cluster::Resources::new(2000, 2000, 1)),
        )
        .container(ContainerTemplate::new("rid-init-label", ops::label_op()));

    // Block needs a models list-artifact; iteration 0 trains from the
    // bootstrap labels, so main runs: gen → label → train0 → block^n
    let mut main = Steps::new("main")
        .then(
            Step::new("gen-confs", "rid-gen")
                .param("count", cfg.n_walkers as i64)
                .param("seed", seed)
                .param("jitter", 0.08f64),
        )
        .then(Step::new("init-label", "rid-init-label").artifact(
            "configs",
            ArtSrc::StepOutput { step: "gen-confs".into(), name: "configs".into() },
        ))
        .then(
            Step::new("init-train", "rid-train")
                .param("steps", cfg.train_steps as i64)
                .param("member", crate::apps::index_list(cfg.n_train))
                .param("tag", "init")
                .artifact(
                    "dataset",
                    ArtSrc::StepOutput { step: "init-label".into(), name: "dataset".into() },
                )
                .slices(
                    Slices::over("member")
                        .stack_artifact("params")
                        .stack("final_loss")
                        .parallelism(cfg.n_train),
                )
                .key("train-init-{{item}}"),
        );
    let mut prev = ("init-label".to_string(), "init-train".to_string(), "gen-confs".to_string());
    for i in 0..cfg.iterations {
        let name = format!("block-{i}");
        main = main.then(
            Step::new(&name, "rid-block")
                .param("iter", i as i64)
                .artifact(
                    "dataset",
                    ArtSrc::StepOutput { step: prev.0.clone(), name: "dataset".into() },
                )
                .artifact(
                    "dataset_models",
                    ArtSrc::StepOutput { step: prev.1.clone(), name: if i == 0 { "params".into() } else { "models".into() } },
                )
                .artifact(
                    "conformations",
                    ArtSrc::StepOutput { step: prev.2.clone(), name: if i == 0 { "configs".into() } else { "conformations".into() } },
                ),
        );
        prev = (name.clone(), name.clone(), name);
    }
    // surface the last block's accumulated products as workflow outputs
    let (models_out, confs_out) = if cfg.iterations == 0 {
        ("params", "configs")
    } else {
        ("models", "conformations")
    };
    main = main
        .out_artifact_from("dataset", &prev.0, "dataset")
        .out_artifact_from("models", &prev.1, models_out)
        .out_artifact_from("conformations", &prev.2, confs_out);
    wf.steps(block_steps(cfg)).steps(main).entrypoint("main")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rid_workflow_validates() {
        workflow(&RidConfig::default(), 3).validate().unwrap();
    }

    #[test]
    fn rid_block_has_four_phases() {
        let b = block_steps(&RidConfig::default());
        // exploration, gather, devi, selection, labeling, collect, training
        assert!(b.groups.len() >= 6);
        assert!(b.io.output_artifacts.contains_key("models"));
    }
}
