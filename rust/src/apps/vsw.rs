//! Virtual Screening Workflow (paper §3.5, Fig. 7): the multi-stage
//! screening funnel of the Hermite platform.
//!
//! Stages (each its own OP / container image, "rendering each runtime
//! environment more streamlined and agile"):
//!
//! 1. **Molecular docking** (Uni-Dock surrogate) in Fast mode over the full
//!    sharded library — Slices segment the library so "the computational
//!    load on any given node is completed within a half-hour window";
//!    `continue_on_success_ratio` keeps the funnel alive under partial
//!    failure, and per-shard keys (`dock-<i>`) let a restart "selectively
//!    address and recompute the problematic molecules without redundantly
//!    reprocessing successful nodes".
//! 2. **Conformation optimization + filtering** (OpenMM/TorsionLibrary
//!    surrogate): Balance-mode re-dock of the top-K.
//! 3. **Free-energy rescoring** (Uni-GBSA surrogate): Detail-mode pass.
//! 4. **Interaction analysis** (ProLIF surrogate): statistics for decision
//!    support.

use crate::core::{
    ArtSrc, ContainerTemplate, ContinueOn, ParamSrc, ParamType, Signature, Slices, Step,
    StepPolicy, Steps, Workflow,
};
use crate::science::ops;

/// Funnel configuration.
#[derive(Debug, Clone)]
pub struct VswConfig {
    /// Library shards (molecules = shards × 256).
    pub n_shards: usize,
    /// Survivors after stage 1 / stage 2 (molecule counts).
    pub k1: usize,
    pub k2: usize,
    /// Minimum shard success ratio per stage.
    pub success_ratio: f64,
    /// Slice parallelism (≈ concurrent "nodes"; paper: 600+).
    pub parallelism: usize,
    /// Retries on transient failures.
    pub retries: u32,
}

impl Default for VswConfig {
    fn default() -> Self {
        VswConfig {
            n_shards: 12,
            k1: 768,
            k2: 256,
            success_ratio: 0.8,
            parallelism: 64,
            retries: 2,
        }
    }
}

fn dock_stage(
    name: &str,
    template: &str,
    n_shards: usize,
    mode: &str,
    library_from: ArtSrc,
    cfg: &VswConfig,
) -> Step {
    let mut retry = StepPolicy::default();
    retry.retries = cfg.retries;
    Step::new(name, template)
        .param("mode", mode)
        .param("noise_seed", crate::apps::index_list(n_shards))
        .artifact("shard", library_from)
        .slices(
            Slices::over("noise_seed")
                .artifact("shard")
                .stack("scores")
                .stack("best")
                .parallelism(cfg.parallelism)
                .continue_on(ContinueOn::SuccessRatio(cfg.success_ratio)),
        )
        .key(&format!("{name}-{{{{item}}}}"))
        .policy(retry)
}

/// Build the VSW funnel workflow.
///
/// Stage shard counts shrink as the funnel narrows: `n_shards` →
/// `ceil(k1/256)` → `ceil(k2/256)`.
pub fn workflow(cfg: &VswConfig, seed: i64) -> Workflow {
    let s1 = cfg.n_shards;
    let s2 = cfg.k1.div_ceil(crate::runtime::shapes::DOCK_BATCH).max(1);
    let s3 = cfg.k2.div_ceil(crate::runtime::shapes::DOCK_BATCH).max(1);

    let wf = Workflow::new("vsw")
        .container(ContainerTemplate::new("vsw-gen", ops::gen_library_op()))
        .container(
            ContainerTemplate::new("vsw-dock", ops::dock_shard_op())
                .image("unidock/gpu:1")
                .resources(crate::cluster::Resources::new(1000, 2000, 1)),
        )
        .container(
            ContainerTemplate::new("vsw-reshard", ops::topk_reshard_op())
                .image("vsw/tools:1"),
        )
        .container(ContainerTemplate::new("vsw-analysis", ops::analysis_op()));

    let mut retry = StepPolicy::default();
    retry.retries = cfg.retries;
    let main = Steps::new("main")
        .signature(Signature::new().out_param("best", ParamType::Float))
        .then(
            Step::new("gen-library", "vsw-gen")
                .param("n_shards", s1 as i64)
                .param("seed", ParamSrc::Const(crate::core::Value::Int(seed)))
                .policy(retry.clone()),
        )
        // stage 1: Fast docking over the whole library
        .then(dock_stage(
            "dock",
            "vsw-dock",
            s1,
            "fast",
            ArtSrc::StepOutput { step: "gen-library".into(), name: "library".into() },
            cfg,
        ))
        .then(
            Step::new("top1", "vsw-reshard")
                .param_from_step("scores", "dock", "scores")
                .param("k", cfg.k1 as i64)
                .policy(retry.clone())
                .artifact(
                    "library",
                    ArtSrc::StepOutput { step: "gen-library".into(), name: "library".into() },
                ),
        )
        // stage 2: conformation optimization + filtering (Balance mode)
        .then(dock_stage(
            "optimize",
            "vsw-dock",
            s2,
            "balance",
            ArtSrc::StepOutput { step: "top1".into(), name: "library".into() },
            cfg,
        ))
        .then(
            Step::new("top2", "vsw-reshard")
                .param_from_step("scores", "optimize", "scores")
                .param("k", cfg.k2 as i64)
                .policy(retry.clone())
                .artifact(
                    "library",
                    ArtSrc::StepOutput { step: "top1".into(), name: "library".into() },
                ),
        )
        // stage 3: free-energy rescoring (Detail mode ≙ MM-GB/PBSA)
        .then(dock_stage(
            "gbsa",
            "vsw-dock",
            s3,
            "detail",
            ArtSrc::StepOutput { step: "top2".into(), name: "library".into() },
            cfg,
        ))
        // stage 4: interaction analysis
        .then(
            Step::new("analysis", "vsw-analysis")
                .param_from_step("scores", "gbsa", "scores")
                .policy(retry),
        )
        .out_param_from("best", "analysis", "best")
        .out_param_from("mean", "analysis", "mean")
        .out_param_from("n_final", "analysis", "n")
        .out_param_from("cutoff1", "top1", "cutoff")
        .out_param_from("cutoff2", "top2", "cutoff");

    wf.steps(main).entrypoint("main")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vsw_workflow_validates() {
        workflow(&VswConfig::default(), 11).validate().unwrap();
    }

    #[test]
    fn vsw_scales_to_paper_shape() {
        // paper: ~1,500 OPs, >1,200 concurrent nodes — representable
        let cfg = VswConfig { n_shards: 1400, parallelism: 1300, ..Default::default() };
        workflow(&cfg, 1).validate().unwrap();
    }

    #[test]
    fn funnel_narrows() {
        let cfg = VswConfig::default();
        assert!(cfg.k1 > cfg.k2);
        let s2 = cfg.k1.div_ceil(256);
        assert!(s2 < cfg.n_shards);
    }
}
