//! Observability: metrics registry + workflow event trace.
//!
//! The paper emphasizes that Dflow is "highly observable" (web UI, CLI,
//! status tracking). In library form that means: every engine action emits a
//! [`Event`] into a bounded trace, and hot-path counters/timers live in a
//! lock-free [`Registry`]. The CLI (`dflow get/watch`) and the benches read
//! these; `timeline_json` exports a Gantt-style view per step.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::jsonx::Json;
use crate::util::epoch_ms;

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Nanosecond-resolution duration accumulator (sum + count → mean).
#[derive(Default)]
pub struct Timer {
    total_ns: AtomicU64,
    count: AtomicU64,
    max_ns: AtomicU64,
}

impl Timer {
    /// Record one observation.
    pub fn observe(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean observation, or zero if empty.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.total_ns.load(Ordering::Relaxed) / c)
    }

    /// Maximum observation.
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Total accumulated time.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.total_ns.load(Ordering::Relaxed))
    }
}

/// Engine-level metrics. One instance per [`crate::engine::Engine`].
#[derive(Default)]
pub struct Registry {
    /// Steps that reached a terminal phase.
    pub steps_succeeded: Counter,
    pub steps_failed: Counter,
    pub steps_skipped: Counter,
    /// Steps whose outputs were reused from a previous run (§2.5).
    pub steps_reused: Counter,
    /// Retry attempts consumed (§2.4).
    pub retries: Counter,
    /// Steps killed by timeout (§2.4).
    pub timeouts: Counter,
    /// Pods that went through the cluster simulator.
    pub pods_scheduled: Counter,
    pub pods_rejected: Counter,
    /// Leaf executions routed through the multi-backend placement layer.
    pub placements: Counter,
    /// Placement requests failed fast as infeasible (no backend could ever
    /// satisfy them).
    pub placement_rejected: Counter,
    /// Engine dispatch latency (ready → running).
    pub dispatch: Timer,
    /// OP execution wall time.
    pub op_exec: Timer,
    /// PJRT execute calls on the request path.
    pub pjrt_calls: Counter,
    pub pjrt_time: Timer,
}

impl Registry {
    /// Dump all metrics as JSON (for `dflow get` and EXPERIMENTS.md).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps_succeeded", Json::n(self.steps_succeeded.get() as f64)),
            ("steps_failed", Json::n(self.steps_failed.get() as f64)),
            ("steps_skipped", Json::n(self.steps_skipped.get() as f64)),
            ("steps_reused", Json::n(self.steps_reused.get() as f64)),
            ("retries", Json::n(self.retries.get() as f64)),
            ("timeouts", Json::n(self.timeouts.get() as f64)),
            ("pods_scheduled", Json::n(self.pods_scheduled.get() as f64)),
            ("pods_rejected", Json::n(self.pods_rejected.get() as f64)),
            ("placements", Json::n(self.placements.get() as f64)),
            ("placement_rejected", Json::n(self.placement_rejected.get() as f64)),
            ("dispatch_mean_us", Json::n(self.dispatch.mean().as_secs_f64() * 1e6)),
            ("dispatch_max_us", Json::n(self.dispatch.max().as_secs_f64() * 1e6)),
            ("op_exec_mean_ms", Json::n(self.op_exec.mean().as_secs_f64() * 1e3)),
            ("pjrt_calls", Json::n(self.pjrt_calls.get() as f64)),
            ("pjrt_mean_ms", Json::n(self.pjrt_time.mean().as_secs_f64() * 1e3)),
        ])
    }
}

/// What happened, when, to which step. The phase names mirror Argo's.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    WorkflowStarted,
    WorkflowSucceeded,
    WorkflowFailed,
    StepPending,
    StepRunning,
    StepSucceeded,
    StepFailed,
    StepSkipped,
    StepReused,
    StepRetrying,
    StepTimedOut,
    PodBound,
    PodReleased,
    /// A leaf execution was routed to a backend by the placement layer
    /// (detail = backend name).
    StepPlaced,
    /// The backend lease of a leaf execution was returned (detail =
    /// backend name). Emitted when the OP actually stops.
    BackendReleased,
}

/// One trace record.
#[derive(Debug, Clone)]
pub struct Event {
    pub at_ms: u64,
    pub kind: EventKind,
    pub step: String,
    pub detail: String,
}

/// Bounded, thread-safe event trace.
pub struct Trace {
    events: Mutex<Vec<Event>>,
    cap: usize,
}

impl Trace {
    /// Create a trace holding at most `cap` events (older dropped).
    pub fn new(cap: usize) -> Self {
        Trace { events: Mutex::new(Vec::new()), cap }
    }

    /// Append an event. `cap == 0` disables tracing entirely (hot-path
    /// fast-out: no lock, no allocation).
    pub fn push(&self, kind: EventKind, step: &str, detail: impl Into<String>) {
        if self.cap == 0 {
            return;
        }
        let mut ev = self.events.lock().unwrap();
        if ev.len() == self.cap {
            ev.remove(0);
        }
        ev.push(Event { at_ms: epoch_ms(), kind, step: step.to_string(), detail: detail.into() });
    }

    /// Snapshot of current events.
    pub fn snapshot(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Number of stored events.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// True when no events are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Export a Gantt-style timeline: for each step, start/end/phase.
    pub fn timeline_json(&self) -> Json {
        let ev = self.events.lock().unwrap();
        let mut spans: BTreeMap<String, (u64, u64, String)> = BTreeMap::new();
        for e in ev.iter() {
            match e.kind {
                EventKind::StepRunning => {
                    spans.entry(e.step.clone()).or_insert((e.at_ms, e.at_ms, "Running".into())).0 =
                        e.at_ms;
                }
                EventKind::StepSucceeded | EventKind::StepFailed | EventKind::StepSkipped => {
                    let s = spans.entry(e.step.clone()).or_insert((e.at_ms, e.at_ms, String::new()));
                    s.1 = e.at_ms;
                    s.2 = format!("{:?}", e.kind);
                }
                _ => {}
            }
        }
        Json::Arr(
            spans
                .into_iter()
                .map(|(step, (start, end, phase))| {
                    Json::obj(vec![
                        ("step", Json::s(step)),
                        ("start_ms", Json::n(start as f64)),
                        ("end_ms", Json::n(end as f64)),
                        ("phase", Json::s(phase)),
                    ])
                })
                .collect(),
        )
    }
}

impl Default for Trace {
    fn default() -> Self {
        Trace::new(100_000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn timer_mean_and_max() {
        let t = Timer::default();
        t.observe(Duration::from_millis(10));
        t.observe(Duration::from_millis(30));
        assert_eq!(t.count(), 2);
        assert_eq!(t.mean(), Duration::from_millis(20));
        assert_eq!(t.max(), Duration::from_millis(30));
    }

    #[test]
    fn trace_bounded() {
        let tr = Trace::new(3);
        for i in 0..5 {
            tr.push(EventKind::StepRunning, &format!("s{i}"), "");
        }
        let ev = tr.snapshot();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].step, "s2");
    }

    #[test]
    fn timeline_builds_spans() {
        let tr = Trace::default();
        tr.push(EventKind::StepRunning, "a", "");
        tr.push(EventKind::StepSucceeded, "a", "");
        let tl = tr.timeline_json();
        let arr = tl.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("phase").unwrap().as_str().unwrap(), "StepSucceeded");
    }

    #[test]
    fn registry_json_has_keys() {
        let r = Registry::default();
        r.steps_succeeded.add(2);
        let j = r.to_json();
        assert_eq!(j.get("steps_succeeded").unwrap().as_i64(), Some(2));
    }
}
