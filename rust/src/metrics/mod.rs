//! Observability: metrics registry + workflow event trace.
//!
//! The paper emphasizes that Dflow is "highly observable" (web UI, CLI,
//! status tracking). In library form that means: every engine action emits a
//! [`Event`] into a bounded trace, and hot-path counters/timers live in a
//! lock-free [`Registry`]. The CLI (`dflow get/watch`) and the benches read
//! these; `timeline_json` exports a Gantt-style view per step.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::jsonx::Json;
use crate::obs::hist::saturating_fetch_add;
use crate::obs::{Histogram, MetricsDoc};
use crate::util::epoch_ms;

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Nanosecond-resolution duration accumulator (sum + count → mean).
///
/// Legacy mean/max-only surface; the registry's latency metrics are
/// [`Histogram`]s now (tail quantiles, mergeable), but `Timer` remains for
/// callers that only need a cheap mean.
#[derive(Default)]
pub struct Timer {
    total_ns: AtomicU64,
    count: AtomicU64,
    max_ns: AtomicU64,
}

impl Timer {
    /// Record one observation. The nanosecond sum saturates instead of
    /// wrapping: a long-lived daemon (~585 years of observed nanoseconds,
    /// but far less with double-counted or adversarial durations) pins at
    /// `u64::MAX` rather than resetting the mean to garbage.
    pub fn observe(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        saturating_fetch_add(&self.total_ns, ns);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean observation, or zero if empty.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.total_ns.load(Ordering::Relaxed) / c)
    }

    /// Maximum observation.
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Total accumulated time.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.total_ns.load(Ordering::Relaxed))
    }
}

/// Engine-level metrics. One instance per [`crate::engine::Engine`].
#[derive(Default)]
pub struct Registry {
    /// Steps that reached a terminal phase.
    pub steps_succeeded: Counter,
    pub steps_failed: Counter,
    pub steps_skipped: Counter,
    /// Steps whose outputs were reused from a previous run (§2.5).
    pub steps_reused: Counter,
    /// Retry attempts consumed (§2.4).
    pub retries: Counter,
    /// Steps killed by timeout (§2.4).
    pub timeouts: Counter,
    /// Pods that went through the cluster simulator.
    pub pods_scheduled: Counter,
    pub pods_rejected: Counter,
    /// Leaf executions routed through the multi-backend placement layer.
    pub placements: Counter,
    /// Placement requests failed fast as infeasible (no backend could ever
    /// satisfy them).
    pub placement_rejected: Counter,
    /// Queued placements preempted by a higher-priority request (the
    /// victim's attempt re-queues; no work was lost).
    pub evictions: Counter,
    /// Attempts whose backend died (or whose node was cordoned) mid-flight
    /// and were re-placed on a surviving backend.
    pub failovers: Counter,
    /// Objects deleted by the engine when reclaiming a failed attempt's
    /// artifact namespace.
    pub artifacts_reclaimed: Counter,
    /// Journal appends that failed (the run keeps going, but its durable
    /// history has a gap — surfaced so operators notice).
    pub journal_errors: Counter,
    /// Encoded log bytes flushed to the `.logs/` namespace (flight
    /// recorder).
    pub log_bytes: Counter,
    /// Log-buffer flushes (one per attempt that logged anything).
    pub log_flushes: Counter,
    /// Engine dispatch latency (ready → running) — log-linear histogram
    /// (p50/p90/p99/max), mergeable across runs for fleet aggregation.
    pub dispatch: Histogram,
    /// OP execution wall time.
    pub op_exec: Histogram,
    /// PJRT execute calls on the request path.
    pub pjrt_calls: Counter,
    pub pjrt_time: Histogram,
    /// Journal append latency as observed by the run's event writes.
    pub journal_append: Histogram,
}

impl Registry {
    /// Dump all metrics as JSON (for `dflow get` and EXPERIMENTS.md).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps_succeeded", Json::n(self.steps_succeeded.get() as f64)),
            ("steps_failed", Json::n(self.steps_failed.get() as f64)),
            ("steps_skipped", Json::n(self.steps_skipped.get() as f64)),
            ("steps_reused", Json::n(self.steps_reused.get() as f64)),
            ("retries", Json::n(self.retries.get() as f64)),
            ("timeouts", Json::n(self.timeouts.get() as f64)),
            ("pods_scheduled", Json::n(self.pods_scheduled.get() as f64)),
            ("pods_rejected", Json::n(self.pods_rejected.get() as f64)),
            ("placements", Json::n(self.placements.get() as f64)),
            ("placement_rejected", Json::n(self.placement_rejected.get() as f64)),
            ("evictions", Json::n(self.evictions.get() as f64)),
            ("failovers", Json::n(self.failovers.get() as f64)),
            ("artifacts_reclaimed", Json::n(self.artifacts_reclaimed.get() as f64)),
            ("journal_errors", Json::n(self.journal_errors.get() as f64)),
            ("log_bytes", Json::n(self.log_bytes.get() as f64)),
            ("log_flushes", Json::n(self.log_flushes.get() as f64)),
            ("dispatch_mean_us", Json::n(self.dispatch.mean().as_secs_f64() * 1e6)),
            ("dispatch_p99_us", Json::n(self.dispatch.p99().as_secs_f64() * 1e6)),
            ("dispatch_max_us", Json::n(self.dispatch.max().as_secs_f64() * 1e6)),
            ("op_exec_mean_ms", Json::n(self.op_exec.mean().as_secs_f64() * 1e3)),
            ("op_exec_p50_ms", Json::n(self.op_exec.p50().as_secs_f64() * 1e3)),
            ("op_exec_p99_ms", Json::n(self.op_exec.p99().as_secs_f64() * 1e3)),
            ("pjrt_calls", Json::n(self.pjrt_calls.get() as f64)),
            ("pjrt_mean_ms", Json::n(self.pjrt_time.mean().as_secs_f64() * 1e3)),
            ("journal_append_p99_us", Json::n(self.journal_append.p99().as_secs_f64() * 1e6)),
        ])
    }

    /// Fold `other` into `self`: counters add, histograms merge
    /// bucket-wise. The engine folds every closed run's registry into an
    /// engine-lifetime aggregate this way, and `export_metrics` merges the
    /// still-live runs on top.
    pub fn merge_from(&self, other: &Registry) {
        self.steps_succeeded.add(other.steps_succeeded.get());
        self.steps_failed.add(other.steps_failed.get());
        self.steps_skipped.add(other.steps_skipped.get());
        self.steps_reused.add(other.steps_reused.get());
        self.retries.add(other.retries.get());
        self.timeouts.add(other.timeouts.get());
        self.pods_scheduled.add(other.pods_scheduled.get());
        self.pods_rejected.add(other.pods_rejected.get());
        self.placements.add(other.placements.get());
        self.placement_rejected.add(other.placement_rejected.get());
        self.evictions.add(other.evictions.get());
        self.failovers.add(other.failovers.get());
        self.artifacts_reclaimed.add(other.artifacts_reclaimed.get());
        self.journal_errors.add(other.journal_errors.get());
        self.log_bytes.add(other.log_bytes.get());
        self.log_flushes.add(other.log_flushes.get());
        self.pjrt_calls.add(other.pjrt_calls.get());
        self.dispatch.merge_from(&other.dispatch);
        self.op_exec.merge_from(&other.op_exec);
        self.pjrt_time.merge_from(&other.pjrt_time);
        self.journal_append.merge_from(&other.journal_append);
    }

    /// Render every counter and latency summary into a [`MetricsDoc`]
    /// under the `dflow_` prefix (durations in seconds — Prometheus
    /// convention; `dflow metrics` exposes the result).
    pub fn export_into(&self, doc: &mut MetricsDoc) {
        doc.counter(
            "dflow_steps_succeeded_total",
            "Steps that reached Succeeded.",
            self.steps_succeeded.get(),
        );
        doc.counter(
            "dflow_steps_failed_total",
            "Steps that reached Failed.",
            self.steps_failed.get(),
        );
        doc.counter(
            "dflow_steps_skipped_total",
            "Steps skipped by when-conditions.",
            self.steps_skipped.get(),
        );
        doc.counter(
            "dflow_steps_reused_total",
            "Steps spliced in from previous runs.",
            self.steps_reused.get(),
        );
        doc.counter("dflow_retries_total", "Retry attempts consumed.", self.retries.get());
        doc.counter("dflow_timeouts_total", "Attempts killed by timeout.", self.timeouts.get());
        doc.counter(
            "dflow_pods_scheduled_total",
            "Pods bound on the cluster.",
            self.pods_scheduled.get(),
        );
        doc.counter(
            "dflow_pods_rejected_total",
            "Pod requests rejected as infeasible.",
            self.pods_rejected.get(),
        );
        doc.counter(
            "dflow_placements_total",
            "Attempts placed on a backend.",
            self.placements.get(),
        );
        doc.counter(
            "dflow_placements_rejected_total",
            "Placement requests failed as infeasible.",
            self.placement_rejected.get(),
        );
        doc.counter(
            "dflow_evictions_total",
            "Queued placements preempted by priority.",
            self.evictions.get(),
        );
        doc.counter(
            "dflow_failovers_total",
            "Attempts re-placed after backend death.",
            self.failovers.get(),
        );
        doc.counter(
            "dflow_artifacts_reclaimed_total",
            "Objects deleted reclaiming failed attempts.",
            self.artifacts_reclaimed.get(),
        );
        doc.counter(
            "dflow_journal_errors_total",
            "Journal appends that failed.",
            self.journal_errors.get(),
        );
        doc.counter(
            "dflow_log_bytes_total",
            "Encoded log bytes flushed to the .logs/ namespace.",
            self.log_bytes.get(),
        );
        doc.counter(
            "dflow_log_flushes_total",
            "Attempt log-buffer flushes.",
            self.log_flushes.get(),
        );
        doc.counter("dflow_pjrt_calls_total", "PJRT execute calls.", self.pjrt_calls.get());
        doc.summary(
            "dflow_dispatch_seconds",
            "Dispatch latency, ready to running.",
            &[],
            &self.dispatch.summary(),
        );
        doc.summary(
            "dflow_op_exec_seconds",
            "OP execution wall time.",
            &[],
            &self.op_exec.summary(),
        );
        doc.summary(
            "dflow_pjrt_seconds",
            "PJRT execute wall time.",
            &[],
            &self.pjrt_time.summary(),
        );
        doc.summary(
            "dflow_journal_append_seconds",
            "Journal append latency.",
            &[],
            &self.journal_append.summary(),
        );
    }
}

/// Labeled counter family: one monotonic counter per string label (tenant
/// names, workflow names, backends, ...). The service control plane's
/// per-tenant accounting surface: coarser than a full metrics registry per
/// tenant, cheap enough to tax every admission decision.
#[derive(Default)]
pub struct LabelCounters {
    map: Mutex<BTreeMap<String, u64>>,
}

impl LabelCounters {
    /// Increment `label` by one.
    pub fn inc(&self, label: &str) {
        self.add(label, 1);
    }

    /// Add `n` to `label`.
    pub fn add(&self, label: &str, n: u64) {
        *self.map.lock().unwrap().entry(label.to_string()).or_insert(0) += n;
    }

    /// Keep the high-water mark of `v` for `label` (gauges like peak live
    /// runs per tenant).
    pub fn record_max(&self, label: &str, v: u64) {
        let mut m = self.map.lock().unwrap();
        let e = m.entry(label.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    /// Current value for `label` (0 if never touched).
    pub fn get(&self, label: &str) -> u64 {
        self.map.lock().unwrap().get(label).copied().unwrap_or(0)
    }

    /// All labels and values.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.map.lock().unwrap().clone()
    }

    /// Sum across labels.
    pub fn total(&self) -> u64 {
        self.map.lock().unwrap().values().sum()
    }

    /// JSON object `{label: value}`.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.map
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), Json::n(*v as f64)))
                .collect(),
        )
    }
}

/// What happened, when, to which step. The phase names mirror Argo's.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    WorkflowStarted,
    WorkflowSucceeded,
    WorkflowFailed,
    StepPending,
    StepRunning,
    StepSucceeded,
    StepFailed,
    StepSkipped,
    StepReused,
    StepRetrying,
    StepTimedOut,
    PodBound,
    PodReleased,
    /// A leaf execution was routed to a backend by the placement layer
    /// (detail = backend name).
    StepPlaced,
    /// The backend lease of a leaf execution was returned (detail =
    /// backend name). Emitted when the OP actually stops.
    BackendReleased,
    /// `WorkflowRun::cancel` was called on a live run (detail = reason);
    /// the run closes as `Cancelled` once in-flight OPs stop.
    RunCancelRequested,
    /// A queued placement was preempted by a higher-priority request
    /// (detail = the evictor); the attempt re-queues.
    StepEvicted,
    /// An attempt's backend died (or its node was cordoned) mid-flight;
    /// the attempt fails over to a surviving backend (detail = what died).
    StepFailedOver,
}

/// One trace record. `seq` is assigned under the ring lock, so it is the
/// exact insertion order: snapshot consumers can rely on strictly
/// increasing `seq` even after the ring wrapped and dropped old events
/// (`at_ms` alone cannot promise that — wall clocks tie and step back).
#[derive(Debug, Clone)]
pub struct Event {
    pub seq: u64,
    pub at_ms: u64,
    pub kind: EventKind,
    pub step: String,
    pub detail: String,
}

/// Mirror hook invoked once per pushed event, after the event was stored
/// (outside the ring lock, so a slow sink never blocks other writers on
/// the ring). The engine uses this to mirror trace events into the durable
/// run journal (`crate::journal`).
pub type TraceSink = Arc<dyn Fn(&Event) + Send + Sync>;

/// Ring state guarded by one mutex: the sequence counter lives **inside**
/// the lock so `seq` order and insertion order can never diverge — the fix
/// for the wrap-ordering bug where a seq drawn before the lock could be
/// stored after a later one.
struct TraceBuf {
    events: VecDeque<Event>,
    next_seq: u64,
}

/// Bounded, thread-safe event trace (O(1) ring eviction).
pub struct Trace {
    buf: Mutex<TraceBuf>,
    cap: usize,
    /// Mirror sink plus its interest predicate: only kinds the predicate
    /// accepts are cloned and handed to the sink, so mirroring three
    /// capacity kinds does not tax every other push with an allocation.
    sink: Option<(fn(&EventKind) -> bool, TraceSink)>,
}

impl Trace {
    /// Create a trace holding at most `cap` events (older dropped).
    pub fn new(cap: usize) -> Self {
        Trace::build(cap, None)
    }

    /// Like [`Trace::new`], with a mirror sink that observes every pushed
    /// event whose kind `interest` accepts — even when `cap == 0` (ring
    /// disabled, mirror still fed).
    pub fn with_sink(cap: usize, interest: fn(&EventKind) -> bool, sink: TraceSink) -> Self {
        Trace::build(cap, Some((interest, sink)))
    }

    fn build(cap: usize, sink: Option<(fn(&EventKind) -> bool, TraceSink)>) -> Self {
        Trace {
            buf: Mutex::new(TraceBuf { events: VecDeque::new(), next_seq: 0 }),
            cap,
            sink,
        }
    }

    /// Append an event. `cap == 0` without an interested sink disables
    /// tracing for this push entirely (hot-path fast-out: no lock, no
    /// allocation).
    pub fn push(&self, kind: EventKind, step: &str, detail: impl Into<String>) {
        let interesting = self.sink.as_ref().map_or(false, |(f, _)| f(&kind));
        if self.cap == 0 && !interesting {
            return;
        }
        let mut b = self.buf.lock().unwrap();
        let ev = Event {
            seq: b.next_seq,
            at_ms: epoch_ms(),
            kind,
            step: step.to_string(),
            detail: detail.into(),
        };
        b.next_seq = b.next_seq.wrapping_add(1);
        let mirrored = if interesting {
            self.sink.as_ref().map(|(_, s)| (Arc::clone(s), ev.clone()))
        } else {
            None
        };
        if self.cap > 0 {
            if b.events.len() == self.cap {
                b.events.pop_front();
            }
            b.events.push_back(ev);
        }
        drop(b);
        if let Some((sink, ev)) = mirrored {
            sink(&ev);
        }
    }

    /// Snapshot of current events (insertion order; `seq` strictly
    /// increasing).
    pub fn snapshot(&self) -> Vec<Event> {
        self.buf.lock().unwrap().events.iter().cloned().collect()
    }

    /// Number of stored events.
    pub fn len(&self) -> usize {
        self.buf.lock().unwrap().events.len()
    }

    /// True when no events are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Export a Gantt-style timeline: for each step, start/end/phase.
    pub fn timeline_json(&self) -> Json {
        let b = self.buf.lock().unwrap();
        let mut spans: BTreeMap<String, (u64, u64, String)> = BTreeMap::new();
        for e in b.events.iter() {
            match e.kind {
                EventKind::StepRunning => {
                    spans.entry(e.step.clone()).or_insert((e.at_ms, e.at_ms, "Running".into())).0 =
                        e.at_ms;
                }
                EventKind::StepSucceeded | EventKind::StepFailed | EventKind::StepSkipped => {
                    let s = spans.entry(e.step.clone()).or_insert((e.at_ms, e.at_ms, String::new()));
                    s.1 = e.at_ms;
                    s.2 = format!("{:?}", e.kind);
                }
                _ => {}
            }
        }
        Json::Arr(
            spans
                .into_iter()
                .map(|(step, (start, end, phase))| {
                    Json::obj(vec![
                        ("step", Json::s(step)),
                        ("start_ms", Json::n(start as f64)),
                        ("end_ms", Json::n(end as f64)),
                        ("phase", Json::s(phase)),
                    ])
                })
                .collect(),
        )
    }
}

impl Default for Trace {
    fn default() -> Self {
        Trace::new(100_000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn timer_mean_and_max() {
        let t = Timer::default();
        t.observe(Duration::from_millis(10));
        t.observe(Duration::from_millis(30));
        assert_eq!(t.count(), 2);
        assert_eq!(t.mean(), Duration::from_millis(20));
        assert_eq!(t.max(), Duration::from_millis(30));
    }

    #[test]
    fn timer_sum_saturates_instead_of_wrapping() {
        // regression: two near-u64::MAX observations used to wrap the
        // nanosecond sum back to ~0, resetting the mean to garbage
        let t = Timer::default();
        let near_max = Duration::from_nanos(u64::MAX - 10);
        t.observe(near_max);
        t.observe(near_max);
        assert_eq!(t.count(), 2);
        assert_eq!(t.total(), Duration::from_nanos(u64::MAX), "sum must pin, not wrap");
        assert_eq!(t.max(), near_max);
        assert!(t.mean() >= Duration::from_nanos(u64::MAX / 2), "mean stays sane after pinning");
    }

    #[test]
    fn registry_merge_folds_counters_and_histograms() {
        let a = Registry::default();
        let b = Registry::default();
        a.steps_succeeded.add(2);
        b.steps_succeeded.add(3);
        b.retries.inc();
        a.dispatch.observe(Duration::from_micros(100));
        b.dispatch.observe(Duration::from_micros(300));
        a.merge_from(&b);
        assert_eq!(a.steps_succeeded.get(), 5);
        assert_eq!(a.retries.get(), 1);
        assert_eq!(a.dispatch.count(), 2);
        assert!(a.dispatch.max() >= Duration::from_micros(300));
        // `b` is untouched
        assert_eq!(b.steps_succeeded.get(), 3);
        assert_eq!(b.dispatch.count(), 1);
    }

    #[test]
    fn trace_bounded() {
        let tr = Trace::new(3);
        for i in 0..5 {
            tr.push(EventKind::StepRunning, &format!("s{i}"), "");
        }
        let ev = tr.snapshot();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].step, "s2");
    }

    #[test]
    fn trace_wrap_keeps_snapshot_order_monotonic_under_concurrent_writers() {
        // regression: seq is assigned under the ring lock, so even with 8
        // writers hammering a tiny ring, the snapshot must be in strict
        // insertion order globally AND per step
        let tr = Arc::new(Trace::new(64));
        let mut handles = Vec::new();
        for t in 0..8 {
            let tr = Arc::clone(&tr);
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    tr.push(EventKind::StepRunning, &format!("s{t}"), format!("{i}"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let ev = tr.snapshot();
        assert_eq!(ev.len(), 64, "ring must hold exactly cap events");
        for w in ev.windows(2) {
            assert!(w[0].seq < w[1].seq, "ring wrap broke snapshot ordering");
        }
        let mut last: BTreeMap<String, i64> = BTreeMap::new();
        for e in &ev {
            let i: i64 = e.detail.parse().unwrap();
            if let Some(prev) = last.insert(e.step.clone(), i) {
                assert!(prev < i, "step {} went backwards: {prev} -> {i}", e.step);
            }
        }
    }

    #[test]
    fn trace_sink_mirrors_even_with_zero_cap_and_honors_interest() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = Arc::clone(&seen);
        let tr = Trace::with_sink(
            0,
            |k| matches!(k, EventKind::PodBound),
            Arc::new(move |e: &Event| s2.lock().unwrap().push(e.kind.clone())),
        );
        tr.push(EventKind::PodBound, "s", "node-1");
        tr.push(EventKind::StepRunning, "s", "not mirrored");
        assert!(tr.is_empty(), "cap 0 keeps the ring empty");
        let got = seen.lock().unwrap().clone();
        assert_eq!(got, vec![EventKind::PodBound], "only interesting kinds reach the sink");
    }

    #[test]
    fn timeline_builds_spans() {
        let tr = Trace::default();
        tr.push(EventKind::StepRunning, "a", "");
        tr.push(EventKind::StepSucceeded, "a", "");
        let tl = tr.timeline_json();
        let arr = tl.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("phase").unwrap().as_str().unwrap(), "StepSucceeded");
    }

    #[test]
    fn label_counters_accumulate_per_label() {
        let c = LabelCounters::default();
        c.inc("alice");
        c.add("alice", 2);
        c.inc("bob");
        c.record_max("peak", 5);
        c.record_max("peak", 3); // lower value must not regress the max
        assert_eq!(c.get("alice"), 3);
        assert_eq!(c.get("bob"), 1);
        assert_eq!(c.get("peak"), 5);
        assert_eq!(c.get("nobody"), 0);
        assert_eq!(c.total(), 9);
        let j = c.to_json();
        assert_eq!(j.get("alice").unwrap().as_i64(), Some(3));
        assert_eq!(c.snapshot().len(), 3);
    }

    #[test]
    fn registry_json_has_keys() {
        let r = Registry::default();
        r.steps_succeeded.add(2);
        let j = r.to_json();
        assert_eq!(j.get("steps_succeeded").unwrap().as_i64(), Some(2));
    }
}
