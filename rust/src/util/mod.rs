//! Small shared utilities: deterministic RNG, id generation, timing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// SplitMix64: tiny, fast, deterministic PRNG. Used everywhere randomness is
/// needed (workload generation, failure injection, property tests) so every
/// run is reproducible from a seed.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from an explicit seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 { 0 } else { self.next_u64() % n }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Approximate standard normal via 12-uniform sum (Irwin–Hall).
    pub fn normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.next_f64();
        }
        s - 6.0
    }

    /// Derive an independent child generator (for parallel reproducibility).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Process-unique monotonically increasing id (node ids, run ids).
pub fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Wall-clock milliseconds since the UNIX epoch.
pub fn epoch_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Scope timer returning elapsed duration from `Instant`.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Elapsed since start.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed in fractional milliseconds.
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a duration as a human-friendly string (ns/µs/ms/s picked by size).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// MD5 digest (RFC 1321) of a byte slice, hex-encoded. Used by the artifact
/// storage plugin surface (`get_md5`, paper §2.8); not for security.
pub fn md5_hex(data: &[u8]) -> String {
    // -- reference implementation, table-driven --
    const S: [u32; 64] = [
        7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 5, 9, 14, 20, 5, 9, 14, 20, 5,
        9, 14, 20, 5, 9, 14, 20, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 6,
        10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
    ];
    const K: [u32; 64] = [
        0xd76aa478, 0xe8c7b756, 0x242070db, 0xc1bdceee, 0xf57c0faf, 0x4787c62a, 0xa8304613,
        0xfd469501, 0x698098d8, 0x8b44f7af, 0xffff5bb1, 0x895cd7be, 0x6b901122, 0xfd987193,
        0xa679438e, 0x49b40821, 0xf61e2562, 0xc040b340, 0x265e5a51, 0xe9b6c7aa, 0xd62f105d,
        0x02441453, 0xd8a1e681, 0xe7d3fbc8, 0x21e1cde6, 0xc33707d6, 0xf4d50d87, 0x455a14ed,
        0xa9e3e905, 0xfcefa3f8, 0x676f02d9, 0x8d2a4c8a, 0xfffa3942, 0x8771f681, 0x6d9d6122,
        0xfde5380c, 0xa4beea44, 0x4bdecfa9, 0xf6bb4b60, 0xbebfbc70, 0x289b7ec6, 0xeaa127fa,
        0xd4ef3085, 0x04881d05, 0xd9d4d039, 0xe6db99e5, 0x1fa27cf8, 0xc4ac5665, 0xf4292244,
        0x432aff97, 0xab9423a7, 0xfc93a039, 0x655b59c3, 0x8f0ccc92, 0xffeff47d, 0x85845dd1,
        0x6fa87e4f, 0xfe2ce6e0, 0xa3014314, 0x4e0811a1, 0xf7537e82, 0xbd3af235, 0x2ad7d2bb,
        0xeb86d391,
    ];
    let mut msg = data.to_vec();
    let bitlen = (data.len() as u64).wrapping_mul(8);
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_le_bytes());

    let (mut a0, mut b0, mut c0, mut d0) =
        (0x67452301u32, 0xefcdab89u32, 0x98badcfeu32, 0x10325476u32);
    for chunk in msg.chunks_exact(64) {
        let mut m = [0u32; 16];
        for (i, w) in chunk.chunks_exact(4).enumerate() {
            m[i] = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        }
        let (mut a, mut b, mut c, mut d) = (a0, b0, c0, d0);
        for i in 0..64 {
            let (f, g) = match i / 16 {
                0 => ((b & c) | (!b & d), i),
                1 => ((d & b) | (!d & c), (5 * i + 1) % 16),
                2 => (b ^ c ^ d, (3 * i + 5) % 16),
                _ => (c ^ (b | !d), (7 * i) % 16),
            };
            let tmp = d;
            d = c;
            c = b;
            let x = a
                .wrapping_add(f)
                .wrapping_add(K[i])
                .wrapping_add(m[g]);
            b = b.wrapping_add(x.rotate_left(S[i]));
            a = tmp;
        }
        a0 = a0.wrapping_add(a);
        b0 = b0.wrapping_add(b);
        c0 = c0.wrapping_add(c);
        d0 = d0.wrapping_add(d);
    }
    let mut out = String::with_capacity(32);
    for v in [a0, b0, c0, d0] {
        for b in v.to_le_bytes() {
            out.push_str(&format!("{b:02x}"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_below_bound() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_normal_has_sane_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let a = next_id();
        let b = next_id();
        assert!(b > a);
    }

    #[test]
    fn md5_known_vectors() {
        // RFC 1321 test vectors
        assert_eq!(md5_hex(b""), "d41d8cd98f00b204e9800998ecf8427e");
        assert_eq!(md5_hex(b"abc"), "900150983cd24fb0d6963f7d28e17f72");
        assert_eq!(
            md5_hex(b"message digest"),
            "f96b697d7cb7938d525a2f31aaf161d0"
        );
        assert_eq!(
            md5_hex(b"abcdefghijklmnopqrstuvwxyz"),
            "c3fcd3d76192e4007dfb496cca67e13b"
        );
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(12)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn fork_produces_independent_streams() {
        let mut root = Rng::new(1);
        let mut a = root.fork();
        let mut b = root.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
