//! Small shared utilities: deterministic RNG, id generation, timing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// SplitMix64: tiny, fast, deterministic PRNG. Used everywhere randomness is
/// needed (workload generation, failure injection, property tests) so every
/// run is reproducible from a seed.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from an explicit seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 { 0 } else { self.next_u64() % n }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Approximate standard normal via 12-uniform sum (Irwin–Hall).
    pub fn normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.next_f64();
        }
        s - 6.0
    }

    /// Derive an independent child generator (for parallel reproducibility).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Event-boundary callback installed by the chaos harness
/// ([`crate::check::chaos::ChaosPlan`]). Subsystems with an installed hook
/// call it at well-defined boundaries (a placement poll, a pod bind, a
/// scheduler job start, a maintenance tick) with a short site label; the
/// plan counts boundaries and fires its scheduled faults at exact counts,
/// which is what makes a chaos schedule deterministic under a seed.
pub type ChaosHook = std::sync::Arc<dyn Fn(&str) + Send + Sync>;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Process-unique monotonically increasing id (node ids, run ids).
pub fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Raise the process id counter so every id issued from now on is ≥ `n`.
/// Used by [`crate::journal::Journal::open`]: a journal written by an
/// earlier process records the run ids that process allocated, and this
/// process must never re-issue one of them (a collision would interleave
/// two unrelated runs in one journal stream). No-op when the counter is
/// already past `n`.
pub fn ensure_next_id_above(n: u64) {
    NEXT_ID.fetch_max(n, Ordering::Relaxed);
}

/// Wall-clock milliseconds since the UNIX epoch.
pub fn epoch_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Scope timer returning elapsed duration from `Instant`.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Elapsed since start.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed in fractional milliseconds.
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a duration as a human-friendly string (ns/µs/ms/s picked by size).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

// -- MD5 (RFC 1321) -----------------------------------------------------------

const MD5_S: [u32; 64] = [
    7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 5, 9, 14, 20, 5, 9, 14, 20, 5,
    9, 14, 20, 5, 9, 14, 20, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 6,
    10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
];
const MD5_K: [u32; 64] = [
    0xd76aa478, 0xe8c7b756, 0x242070db, 0xc1bdceee, 0xf57c0faf, 0x4787c62a, 0xa8304613,
    0xfd469501, 0x698098d8, 0x8b44f7af, 0xffff5bb1, 0x895cd7be, 0x6b901122, 0xfd987193,
    0xa679438e, 0x49b40821, 0xf61e2562, 0xc040b340, 0x265e5a51, 0xe9b6c7aa, 0xd62f105d,
    0x02441453, 0xd8a1e681, 0xe7d3fbc8, 0x21e1cde6, 0xc33707d6, 0xf4d50d87, 0x455a14ed,
    0xa9e3e905, 0xfcefa3f8, 0x676f02d9, 0x8d2a4c8a, 0xfffa3942, 0x8771f681, 0x6d9d6122,
    0xfde5380c, 0xa4beea44, 0x4bdecfa9, 0xf6bb4b60, 0xbebfbc70, 0x289b7ec6, 0xeaa127fa,
    0xd4ef3085, 0x04881d05, 0xd9d4d039, 0xe6db99e5, 0x1fa27cf8, 0xc4ac5665, 0xf4292244,
    0x432aff97, 0xab9423a7, 0xfc93a039, 0x655b59c3, 0x8f0ccc92, 0xffeff47d, 0x85845dd1,
    0x6fa87e4f, 0xfe2ce6e0, 0xa3014314, 0x4e0811a1, 0xf7537e82, 0xbd3af235, 0x2ad7d2bb,
    0xeb86d391,
];

fn md5_compress(state: &mut [u32; 4], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut m = [0u32; 16];
    for (i, w) in block.chunks_exact(4).enumerate() {
        m[i] = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
    }
    let (mut a, mut b, mut c, mut d) = (state[0], state[1], state[2], state[3]);
    for i in 0..64 {
        let (f, g) = match i / 16 {
            0 => ((b & c) | (!b & d), i),
            1 => ((d & b) | (!d & c), (5 * i + 1) % 16),
            2 => (b ^ c ^ d, (3 * i + 5) % 16),
            _ => (c ^ (b | !d), (7 * i) % 16),
        };
        let tmp = d;
        d = c;
        c = b;
        let x = a.wrapping_add(f).wrapping_add(MD5_K[i]).wrapping_add(m[g]);
        b = b.wrapping_add(x.rotate_left(MD5_S[i]));
        a = tmp;
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
}

/// Incremental MD5 (RFC 1321) hasher: `update` with byte runs as they
/// stream past, `finalize_hex` at the end. The storage layer's streaming
/// uploads digest without ever buffering the whole object; not for
/// security.
pub struct Md5 {
    state: [u32; 4],
    buf: [u8; 64],
    buf_len: usize,
    total: u64,
}

impl Default for Md5 {
    fn default() -> Self {
        Md5::new()
    }
}

impl Md5 {
    /// Fresh hasher.
    pub fn new() -> Self {
        Md5 {
            state: [0x67452301, 0xefcdab89, 0x98badcfe, 0x10325476],
            buf: [0u8; 64],
            buf_len: 0,
            total: 0,
        }
    }

    /// Absorb `data`.
    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                md5_compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            md5_compress(&mut self.state, &data[..64]);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Pad, finish, and hex-encode the digest.
    pub fn finalize_hex(mut self) -> String {
        let bitlen = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        self.update(&bitlen.to_le_bytes());
        debug_assert_eq!(self.buf_len, 0);
        let mut out = String::with_capacity(32);
        for v in self.state {
            for b in v.to_le_bytes() {
                out.push_str(&format!("{b:02x}"));
            }
        }
        out
    }
}

/// MD5 digest of a byte slice, hex-encoded. Used by the artifact storage
/// plugin surface (`get_md5`, paper §2.8); not for security.
pub fn md5_hex(data: &[u8]) -> String {
    let mut h = Md5::new();
    h.update(data);
    h.finalize_hex()
}

// -- CRC-32 (IEEE) ------------------------------------------------------------

const fn build_crc32_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// Per-byte CRC-32 values (reflected IEEE polynomial, const-evaluated).
static CRC32_TABLE: [u32; 256] = build_crc32_table();

/// CRC-32 (IEEE 802.3, the zlib/gzip variant) of a byte slice. Guards the
/// run-journal record framing against torn tails and bit rot; not for
/// security.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for b in data {
        c = (c >> 8) ^ CRC32_TABLE[((c ^ *b as u32) & 0xFF) as usize];
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_below_bound() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_normal_has_sane_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let a = next_id();
        let b = next_id();
        assert!(b > a);
    }

    #[test]
    fn ensure_next_id_above_fences_the_counter() {
        let fence = next_id() + 10_000;
        ensure_next_id_above(fence);
        assert!(next_id() >= fence);
        // raising to a lower bound is a no-op
        let cur = next_id();
        ensure_next_id_above(1);
        assert!(next_id() > cur);
    }

    #[test]
    fn crc32_known_vectors() {
        // the classic check value, plus the empty string
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        // sensitive to every byte
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    #[test]
    fn md5_known_vectors() {
        // RFC 1321 test vectors
        assert_eq!(md5_hex(b""), "d41d8cd98f00b204e9800998ecf8427e");
        assert_eq!(md5_hex(b"abc"), "900150983cd24fb0d6963f7d28e17f72");
        assert_eq!(
            md5_hex(b"message digest"),
            "f96b697d7cb7938d525a2f31aaf161d0"
        );
        assert_eq!(
            md5_hex(b"abcdefghijklmnopqrstuvwxyz"),
            "c3fcd3d76192e4007dfb496cca67e13b"
        );
    }

    #[test]
    fn md5_incremental_matches_one_shot() {
        // every split of the input must hash identically to one update
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 31 + 7) as u8).collect();
        let want = md5_hex(&data);
        for split in [0usize, 1, 55, 56, 57, 63, 64, 65, 128, 999, 1000] {
            let mut h = Md5::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize_hex(), want, "split={split}");
        }
        let mut h = Md5::new();
        for b in &data {
            h.update(std::slice::from_ref(b));
        }
        assert_eq!(h.finalize_hex(), want);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(12)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn fork_produces_independent_streams() {
        let mut root = Rng::new(1);
        let mut a = root.fork();
        let mut b = root.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
