//! Minimal JSON value model, parser and serializer.
//!
//! `serde` is not available in the offline vendor set (DESIGN.md
//! substitution table), so status files, the artifact manifest and the
//! restart/reuse records use this self-contained implementation. It supports
//! the full JSON grammar (RFC 8259) minus exotic number edge cases, plus
//! pretty printing.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Maps are ordered (BTreeMap) so serialization is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Str convenience.
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Num convenience.
    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    /// Field access on objects; `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As i64 if numeric and integral.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    /// As str if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool if a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, None, 0);
        s
    }

    /// Serialize compactly into an existing buffer (appends; the caller
    /// owns clearing). Lets hot paths reuse one allocation across
    /// serializations instead of building a fresh `String` each time.
    pub fn write_compact(&self, out: &mut String) {
        write_json(self, out, None, 0);
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(2), 0);
        s
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (depth + 1)));
                }
                write_json(item, out, indent, depth + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * depth));
            }
            out.push(']');
        }
        Json::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (depth + 1)));
                }
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(val, out, indent, depth + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * depth));
            }
            out.push('}');
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v, Json::Str("a\nb\t\"q\" A".into()));
        // and they serialize back parseably
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"caf\u{e9} \u{4e2d}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café 中");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn pretty_is_parseable_and_stable() {
        let v = Json::obj(vec![
            ("z", Json::n(1.0)),
            ("a", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let p = v.to_string_pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
        // keys sorted (BTreeMap)
        assert!(p.find("\"a\"").unwrap() < p.find("\"z\"").unwrap());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::n(5.0).to_string_compact(), "5");
        assert_eq!(Json::n(5.5).to_string_compact(), "5.5");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
    }
}
