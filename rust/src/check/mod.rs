//! Mini property-testing framework.
//!
//! `proptest` is not in the offline vendor set (DESIGN.md substitution
//! table), so coordinator invariants are checked with this self-contained
//! harness: seeded generators over [`crate::util::Rng`], N-case `forall`
//! runs, and failing-seed reporting so any counterexample is reproducible
//! with `CHECK_SEED=<seed>`.

use crate::util::Rng;

pub mod chaos;

pub use chaos::assert_all_drained;

/// Number of cases per property (override with env `CHECK_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("CHECK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Root seed (override with env `CHECK_SEED` to replay a failure).
pub fn root_seed() -> u64 {
    std::env::var("CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDF10_57EE_Du64)
}

/// Run `prop` for `default_cases()` seeded cases; panic with the failing
/// case seed on the first failure.
///
/// ```no_run
/// dflow::check::forall("abs is nonneg", |rng| {
///     let x = rng.next_f64() - 0.5;
///     assert!(x.abs() >= 0.0);
/// });
/// ```
/// (`no_run`: doctest binaries lack the xla rpath in this build image.)
pub fn forall(name: &str, prop: impl FnMut(&mut Rng)) {
    forall_cases(name, default_cases(), prop);
}

/// [`forall`] with an explicit case count (for properties whose contract
/// requires more cases than the default). The `CHECK_CASES` env var still
/// wins when set, so the `CHECK_SEED=<seed> CHECK_CASES=1` replay recipe
/// printed on failure keeps working.
pub fn forall_cases(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    let cases = std::env::var("CHECK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    let mut root = Rng::new(root_seed());
    for case in 0..cases {
        let seed = root.next_u64();
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with CHECK_SEED={seed} CHECK_CASES=1): {msg}"
            );
        }
    }
}

/// Generator helpers over [`Rng`].
pub mod gen {
    use crate::util::Rng;

    /// Vec of length in [lo, hi) with elements from `f`.
    pub fn vec_of<T>(rng: &mut Rng, lo: usize, hi: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let n = lo + rng.below((hi - lo).max(1) as u64) as usize;
        (0..n).map(|_| f(rng)).collect()
    }

    /// Identifier-ish short string.
    pub fn ident(rng: &mut Rng) -> String {
        let n = 1 + rng.below(8) as usize;
        (0..n)
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(rng: &mut Rng, items: &'a [T]) -> &'a T {
        &items[rng.below(items.len() as u64) as usize]
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(rng: &mut Rng, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 parity roundtrip", |rng| {
            let x = rng.next_u64();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "replay with CHECK_SEED=")]
    fn forall_reports_seed_on_failure() {
        forall("always fails", |_| panic!("boom"));
    }

    #[test]
    fn permutation_is_a_permutation() {
        forall("permutation", |rng| {
            let n = 1 + rng.below(50) as usize;
            let mut p = gen::permutation(rng, n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    fn ident_is_nonempty_ascii() {
        forall("ident", |rng| {
            let s = gen::ident(rng);
            assert!(!s.is_empty() && s.bytes().all(|b| b.is_ascii_lowercase()));
        });
    }
}
