//! Deterministic fault-injection toolkit for the chaos battery.
//!
//! Three pieces, all seeded/deterministic so every failing schedule is
//! replayable with the seed the check harness prints:
//!
//! * **Fault-injecting executors** — [`FlakyExecutor`] (seeded transient
//!   failures), [`ProbeExecutor`] (live/peak concurrency accounting) and
//!   [`SwitchedExecutor`] (a [`FaultSwitch`]-gated transient-fault window),
//!   shared by unit tests, integration batteries and the fault-tolerance
//!   benches.
//! * **[`ChaosPlan`]** — a schedule of [`ChaosAction`]s keyed by *event
//!   boundary index*. Every chaos-instrumented subsystem (placer waits,
//!   cluster pod binds, scheduler job dispatch, the service maintenance
//!   tick) fires the installed [`crate::util::ChaosHook`] at its event
//!   boundaries; the plan counts boundaries and fires the actions
//!   scheduled at each count. Backend kills, cordons, HPC capacity flaps
//!   and fault windows thus land *inside* the run, at a reproducible
//!   point, without sleeps or wall-clock coupling.
//! * **[`assert_all_drained`]** — the shared leak audit every battery case
//!   ends with: no leases, pods, partition jobs, blocked workers, cached
//!   journal writers or orphaned CAS chunks survive a run, chaotic or not.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::Cluster;
use crate::core::{ContainerTemplate, OpCtx, OpError};
use crate::engine::{Backend, Engine};
use crate::executor::{Executor, LocalExecutor};
use crate::hpc::HpcScheduler;
use crate::journal::Journal;
use crate::storage::CasStore;
use crate::util::{ChaosHook, Rng};

/// Test/bench executor: fails transiently with probability `rate` before
/// delegating to [`LocalExecutor`]. Counts attempts.
pub struct FlakyExecutor {
    rate: f64,
    rng: Mutex<Rng>,
    /// Total execute calls.
    pub attempts: AtomicU64,
    /// Calls that failed transiently.
    pub injected: AtomicU64,
}

impl FlakyExecutor {
    /// Fail with probability `rate` (deterministic from `seed`).
    pub fn new(rate: f64, seed: u64) -> Self {
        FlakyExecutor {
            rate,
            rng: Mutex::new(Rng::new(seed)),
            attempts: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }
}

impl Executor for FlakyExecutor {
    fn execute(&self, tpl: &ContainerTemplate, ctx: &mut OpCtx) -> Result<(), OpError> {
        self.attempts.fetch_add(1, Ordering::Relaxed);
        if self.rng.lock().unwrap().chance(self.rate) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return Err(OpError::Transient("injected executor failure".into()));
        }
        LocalExecutor.execute(tpl, ctx)
    }

    fn describe(&self) -> String {
        format!("flaky({})", self.rate)
    }
}

/// Test/bench executor decorator: counts live and peak concurrent
/// `execute` calls through an inner executor via a shared
/// [`crate::bench_util::ConcurrencyProbe`]. Wrap each backend's executor
/// with one of these to prove per-backend in-flight executions never
/// exceed that backend's capacity.
pub struct ProbeExecutor {
    inner: Arc<dyn Executor>,
    probe: Arc<crate::bench_util::ConcurrencyProbe>,
}

impl ProbeExecutor {
    /// Wrap `inner`, counting through `probe`.
    pub fn new(inner: Arc<dyn Executor>, probe: Arc<crate::bench_util::ConcurrencyProbe>) -> Self {
        ProbeExecutor { inner, probe }
    }
}

impl Executor for ProbeExecutor {
    fn execute(&self, tpl: &ContainerTemplate, ctx: &mut OpCtx) -> Result<(), OpError> {
        self.probe.with(|| self.inner.execute(tpl, ctx))
    }

    fn describe(&self) -> String {
        format!("probe({})", self.inner.describe())
    }
}

/// Shared on/off gate for a transient-fault window (storage or executor
/// faults that start and stop at chaos-scheduled boundaries rather than
/// with a fixed probability).
#[derive(Default)]
pub struct FaultSwitch {
    on: AtomicBool,
    /// Faults injected while the switch was on.
    pub injected: AtomicU64,
}

impl FaultSwitch {
    /// Fresh switch, initially off.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Open the fault window.
    pub fn set_on(&self) {
        self.on.store(true, Ordering::SeqCst);
    }

    /// Close the fault window.
    pub fn set_off(&self) {
        self.on.store(false, Ordering::SeqCst);
    }

    /// Is the window currently open?
    pub fn is_on(&self) -> bool {
        self.on.load(Ordering::SeqCst)
    }

    /// Consume one fault if the window is open: returns `Some(err)` to
    /// inject, `None` to proceed normally.
    pub fn trip(&self, what: &str) -> Option<OpError> {
        if self.is_on() {
            self.injected.fetch_add(1, Ordering::Relaxed);
            Some(OpError::Transient(format!("injected {what} fault (window open)")))
        } else {
            None
        }
    }
}

/// Executor decorator failing transiently while its [`FaultSwitch`] is on
/// — the window-shaped sibling of [`FlakyExecutor`]'s probability model.
pub struct SwitchedExecutor {
    inner: Arc<dyn Executor>,
    switch: Arc<FaultSwitch>,
}

impl SwitchedExecutor {
    /// Wrap `inner`, gated by `switch`.
    pub fn new(inner: Arc<dyn Executor>, switch: Arc<FaultSwitch>) -> Self {
        SwitchedExecutor { inner, switch }
    }
}

impl Executor for SwitchedExecutor {
    fn execute(&self, tpl: &ContainerTemplate, ctx: &mut OpCtx) -> Result<(), OpError> {
        if let Some(err) = self.switch.trip("executor") {
            return Err(err);
        }
        self.inner.execute(tpl, ctx)
    }

    fn describe(&self) -> String {
        format!("switched({})", self.inner.describe())
    }
}

/// One scheduled fault. Fired by [`ChaosPlan`] when the run reaches the
/// boundary it is scheduled at.
pub enum ChaosAction {
    /// [`Backend::kill`]: in-flight attempts fail over, placements skip it.
    KillBackend(Arc<Backend>),
    /// [`Backend::revive`]: bring a dead/cordoned backend back.
    ReviveBackend(Arc<Backend>),
    /// [`Backend::cordon`]: drain — placements wait, in-flight runs finish.
    CordonBackend(Arc<Backend>),
    /// [`Backend::uncordon`].
    UncordonBackend(Arc<Backend>),
    /// [`Cluster::cordon`] one node: attempts bound to it fail over.
    CordonNode(Arc<Cluster>, String),
    /// [`Cluster::uncordon`] one node.
    UncordonNode(Arc<Cluster>, String),
    /// Flap an HPC partition's capacity
    /// ([`HpcScheduler::set_partition_slots`], clamped to the spec).
    SetPartitionSlots(Arc<HpcScheduler>, String, usize),
    /// Open a [`FaultSwitch`] window.
    FaultsOn(Arc<FaultSwitch>),
    /// Close a [`FaultSwitch`] window.
    FaultsOff(Arc<FaultSwitch>),
    /// Anything else (custom probes, counters).
    Call(Box<dyn Fn() + Send + Sync>),
}

impl ChaosAction {
    fn fire(&self) {
        match self {
            ChaosAction::KillBackend(b) => b.kill(),
            ChaosAction::ReviveBackend(b) => b.revive(),
            ChaosAction::CordonBackend(b) => b.cordon(),
            ChaosAction::UncordonBackend(b) => b.uncordon(),
            ChaosAction::CordonNode(c, n) => {
                c.cordon(n);
            }
            ChaosAction::UncordonNode(c, n) => {
                c.uncordon(n);
            }
            ChaosAction::SetPartitionSlots(s, p, n) => {
                let _ = s.set_partition_slots(p, *n);
            }
            ChaosAction::FaultsOn(s) => s.set_on(),
            ChaosAction::FaultsOff(s) => s.set_off(),
            ChaosAction::Call(f) => f(),
        }
    }
}

/// A deterministic chaos schedule: actions keyed by event-boundary index.
///
/// The hook returned by [`ChaosPlan::hook`] increments one global counter
/// per boundary crossing (placement attempt, pod bind, job dispatch,
/// maintenance tick — the site label is informational) and fires whatever
/// actions are scheduled at that count. With a single-threaded schedule
/// the boundary order is exactly reproducible; under concurrency the
/// counter still gives a *valid* interleaving of the same action set,
/// which is what the battery's invariant-style assertions need.
#[derive(Default)]
pub struct ChaosPlan {
    counter: AtomicU64,
    plan: Mutex<BTreeMap<u64, Vec<ChaosAction>>>,
    fired: AtomicU64,
}

impl ChaosPlan {
    /// Fresh, empty plan.
    pub fn new() -> Arc<ChaosPlan> {
        Arc::new(ChaosPlan::default())
    }

    /// Schedule `action` to fire when the `boundary`-th event boundary is
    /// crossed (0-based; multiple actions per boundary fire in insertion
    /// order).
    pub fn at(&self, boundary: u64, action: ChaosAction) {
        self.plan.lock().unwrap().entry(boundary).or_default().push(action);
    }

    /// The hook to install ([`Engine::set_chaos_hook`] /
    /// [`crate::service::WorkflowService::set_chaos`] /
    /// [`ChaosPlan::install`]). Actions run on the thread that crossed the
    /// boundary; every action is fire-once (removed from the plan).
    pub fn hook(self: &Arc<Self>) -> ChaosHook {
        let plan = Arc::clone(self);
        Arc::new(move |_site: &str| {
            let n = plan.counter.fetch_add(1, Ordering::SeqCst);
            let due = plan.plan.lock().unwrap().remove(&n);
            if let Some(actions) = due {
                for a in &actions {
                    a.fire();
                    plan.fired.fetch_add(1, Ordering::SeqCst);
                }
            }
        })
    }

    /// Install this plan's hook on every boundary `engine` owns.
    pub fn install(self: &Arc<Self>, engine: &Engine) {
        engine.set_chaos_hook(self.hook());
    }

    /// Event boundaries crossed so far.
    pub fn boundaries(&self) -> u64 {
        self.counter.load(Ordering::SeqCst)
    }

    /// Actions fired so far.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::SeqCst)
    }

    /// Scheduled actions not yet fired (a schedule placed beyond the run's
    /// last boundary never fires — callers asserting full delivery should
    /// check this is zero).
    pub fn pending(&self) -> usize {
        self.plan.lock().unwrap().values().map(Vec::len).sum()
    }
}

/// The battery-wide leak audit: panics (with the first offending
/// subsystem named) unless every layer has fully drained.
///
/// * every placement backend passes [`Backend::audit_drained`] (no
///   leases, bound pods or partition jobs outstanding);
/// * the engine-level cluster, when present, holds no bound pods and has
///   balanced bind/release counters;
/// * the scheduler pool has no workers stuck in a capacity wait;
/// * the journal, when given, holds no cached cross-run writers;
/// * the CAS, when given, garbage-collects **zero** chunks — i.e. every
///   failed/evicted/failed-over attempt's artifacts were already
///   reclaimed through the refcount path, not left for gc.
pub fn assert_all_drained(engine: &Engine, cas: Option<&CasStore>, journal: Option<&Journal>) {
    if let Some(placer) = engine.placer() {
        for b in placer.backends() {
            if let Err(leak) = b.audit_drained() {
                panic!("assert_all_drained: {leak}");
            }
        }
    }
    if let Some(cluster) = engine.cluster() {
        let pods = cluster.pods_in_flight();
        assert!(pods == 0, "assert_all_drained: engine cluster has {pods} bound pods");
        let (bound, released, _) = cluster.stats();
        assert!(
            bound == released,
            "assert_all_drained: engine cluster bound {bound} pods but released {released}"
        );
    }
    let sched = engine.scheduler_stats();
    assert!(
        sched.blocked == 0,
        "assert_all_drained: {} scheduler worker(s) still blocked in a capacity wait",
        sched.blocked
    );
    assert!(
        sched.timer_depth == 0,
        "assert_all_drained: {} attempt deadline(s) still armed on the timer wheel",
        sched.timer_depth
    );
    if let Some(j) = journal {
        let writers = j.cached_writers();
        assert!(
            writers.is_empty(),
            "assert_all_drained: journal still caches writers for runs {writers:?}"
        );
    }
    if let Some(c) = cas {
        match c.gc() {
            Ok(report) => assert!(
                report.chunks_reclaimed == 0,
                "assert_all_drained: cas gc reclaimed {} orphaned chunk(s)",
                report.chunks_reclaimed
            ),
            Err(e) => panic!("assert_all_drained: cas not quiescent: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{FnOp, ParamType, Signature, Value};
    use crate::storage::MemStorage;

    fn doubler() -> ContainerTemplate {
        ContainerTemplate::new(
            "double",
            Arc::new(FnOp::new(
                Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
                |ctx| {
                    let x = ctx.get_int("x")?;
                    ctx.set("y", x * 2);
                    Ok(())
                },
            )),
        )
    }

    fn ctx_with_x(x: i64) -> OpCtx {
        let mut c = OpCtx::bare(Arc::new(MemStorage::new()));
        c.inputs.insert("x".into(), Value::Int(x));
        c
    }

    #[test]
    fn flaky_executor_injects() {
        let ex = FlakyExecutor::new(1.0, 1);
        let mut ctx = ctx_with_x(1);
        let err = ex.execute(&doubler(), &mut ctx).unwrap_err();
        assert!(err.is_transient());
        assert_eq!(ex.injected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn flaky_executor_zero_rate_is_local() {
        let ex = FlakyExecutor::new(0.0, 1);
        let mut ctx = ctx_with_x(3);
        ex.execute(&doubler(), &mut ctx).unwrap();
        assert_eq!(ctx.outputs["y"], Value::Int(6));
    }

    #[test]
    fn switched_executor_faults_only_inside_window() {
        let sw = FaultSwitch::new();
        let ex = SwitchedExecutor::new(Arc::new(LocalExecutor), sw.clone());
        let mut ctx = ctx_with_x(2);
        ex.execute(&doubler(), &mut ctx).unwrap();
        sw.set_on();
        let err = ex.execute(&doubler(), &mut ctx_with_x(2)).unwrap_err();
        assert!(err.is_transient());
        sw.set_off();
        ex.execute(&doubler(), &mut ctx_with_x(2)).unwrap();
        assert_eq!(sw.injected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chaos_plan_fires_at_exact_boundary() {
        let plan = ChaosPlan::new();
        let sw = FaultSwitch::new();
        plan.at(2, ChaosAction::FaultsOn(sw.clone()));
        plan.at(4, ChaosAction::FaultsOff(sw.clone()));
        let hook = plan.hook();
        let states: Vec<bool> = (0..6)
            .map(|_| {
                hook("test.boundary");
                sw.is_on()
            })
            .collect();
        // boundary indices 0,1 off; 2,3 on; 4,5 off again
        assert_eq!(states, vec![false, false, true, true, false, false]);
        assert_eq!(plan.boundaries(), 6);
        assert_eq!(plan.fired(), 2);
        assert_eq!(plan.pending(), 0);
    }

    #[test]
    fn chaos_plan_kill_action_fires_backend_watchers() {
        let backend = Arc::new(Backend::local_slots("b", 1));
        let plan = ChaosPlan::new();
        plan.at(0, ChaosAction::KillBackend(backend.clone()));
        let token = crate::core::CancelToken::new();
        let _guard = backend.register_watch(&token);
        assert!(!token.is_cancelled());
        plan.hook()("test.boundary");
        assert!(token.is_cancelled());
        assert_eq!(backend.health(), crate::engine::BackendHealth::Dead);
    }

    #[test]
    fn assert_all_drained_passes_on_fresh_engine() {
        let engine = Engine::local();
        assert_all_drained(&engine, None, None);
    }
}
