//! `dflow` CLI: the command-line face of the workflow service control
//! plane — submit, inspect, watch, cancel, retry and compact runs against
//! a durable store shared by every invocation (and any live service).
//!
//! ```text
//! dflow workflows                       # built-in application workflows
//! dflow lint [name...] [--json] [--deny-warnings]
//!                                       # static analysis against the demo
//!                                       # cluster, without running anything
//! dflow submit <name> [seed]            # run one under the service (journaled)
//! dflow list [--json]                   # registry: every journaled run
//! dflow get <run_id>                    # recovered run state as JSON
//! dflow timeline <run_id> [node-path]   # full event history of a run
//! dflow watch <run_id>                  # tail a run's journal live
//! dflow logs <run_id> [node-path] [--attempt N] [--follow] [--level L] [--json]
//!                                       # captured OP logs: live tail, post-hoc,
//!                                       # cross-process — survives compaction
//! dflow cancel <run_id> [reason]        # durable cancel marker (applied by a live service)
//! dflow retry <name> <run_id> [seed]    # resubmit: only the non-succeeded suffix re-runs
//! dflow compact <run_id>|--all [--purge-logs]
//!                                       # fold closed runs into snapshots
//! dflow profile <run_id> [--json]       # per-phase latency breakdown + critical path
//! dflow top [--json]                    # live fleet view over the shared store
//! dflow metrics [name [seed]] [--json]  # Prometheus-text (or JSON) metrics export,
//!                                       # optionally running one workflow first
//! dflow artifacts | dflow cluster       # AOT inventory / demo topology
//! ```
//!
//! Every store-backed command takes `--store DIR` (default `.dflow-store`,
//! or `$DFLOW_STORE`): a `LocalStorage`-backed journal any number of
//! processes can share — `dflow submit` in one terminal, `dflow watch` +
//! `dflow cancel` in another, is the paper's server/CLI split in two
//! processes. The `demo-*` workflows run without AOT artifacts.

use std::sync::Arc;
use std::time::Duration;

use dflow::apps::{apex, deepks, fpop, rid, tesla, vsw};
use dflow::cluster::{Cluster, NodeSpec, Resources};
use dflow::core::{ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow};
use dflow::engine::Engine;
use dflow::journal::{Appender, Journal, RunRegistry};
use dflow::runtime::Runtime;
use dflow::service::{RunWatch, ServiceConfig, WorkflowService};
use dflow::storage::LocalStorage;

const WORKFLOWS: &[(&str, &str)] = &[
    ("demo-fanout", "Runtime-free sliced fan-out demo (fast)"),
    ("demo-slow", "Runtime-free slow fan-out (for watch/cancel demos)"),
    ("fpop-eos", "FPOP EOS flow (paper Fig. 3)"),
    ("apex-relaxation", "APEX relaxation job (Fig. 4)"),
    ("apex-joint", "APEX joint relaxation+property job (Fig. 4)"),
    ("rid", "Rid-kit reinforced-dynamics loop (Fig. 5)"),
    ("deepks", "DeePKS SCF⇄train loop (Fig. 6)"),
    ("vsw", "Virtual screening funnel (Fig. 7)"),
    ("tesla", "TESLA concurrent-learning loop (Fig. 8)"),
];

/// Sliced square-and-sum fan-out; no PJRT runtime needed.
fn demo_fanout(seed: i64) -> Workflow {
    let sq = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        move |ctx| {
            let x = ctx.get_int("x")?;
            ctx.set("y", x * x + seed);
            Ok(())
        },
    ));
    Workflow::new("demo-fanout")
        .container(ContainerTemplate::new("sq", sq))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "sq")
                        .param("x", Value::ints(0..16))
                        .slices(Slices::over("x").stack("y").parallelism(8)),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main")
}

/// Slow cooperative fan-out (~10 s): each slice checkpoints between
/// sleeps, so `dflow cancel` from another terminal stops it mid-flight —
/// and logs as it goes, so `dflow logs <id> --follow` has something to tail.
fn demo_slow(seed: i64) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        move |ctx| {
            let x = ctx.get_int("x")?;
            ctx.log(dflow::obs::LogLevel::Info, &format!("slice x={x}: starting 2s of work"));
            for i in 0..40 {
                ctx.checkpoint()?; // observes run-level cancel
                std::thread::sleep(Duration::from_millis(50));
                if i % 10 == 9 {
                    ctx.log(dflow::obs::LogLevel::Debug, &format!("slice x={x}: {}/40 ticks", i + 1));
                }
            }
            ctx.set("y", x + seed);
            Ok(())
        },
    ));
    Workflow::new("demo-slow")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("x", Value::ints(0..8))
                        .slices(Slices::over("x").stack("y").parallelism(4)),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main")
}

fn needs_runtime(name: &str) -> bool {
    !name.starts_with("demo-")
}

fn build(name: &str, seed: i64) -> Option<Workflow> {
    let scales = [0.85, 0.9, 0.95, 1.0, 1.05, 1.1, 1.15];
    Some(match name {
        "demo-fanout" => demo_fanout(seed),
        "demo-slow" => demo_slow(seed),
        "fpop-eos" => fpop::eos_workflow(seed, &scales, 2),
        "apex-relaxation" => apex::relaxation_workflow(seed),
        "apex-joint" => apex::joint_workflow(seed, &scales),
        "rid" => rid::workflow(&rid::RidConfig::default(), seed),
        "deepks" => deepks::workflow(&deepks::DeepksConfig::default()),
        "vsw" => vsw::workflow(&vsw::VswConfig::default(), seed),
        "tesla" => tesla::workflow(&tesla::TeslaConfig::default(), seed),
        _ => return None,
    })
}

fn demo_cluster() -> Arc<Cluster> {
    let mut nodes: Vec<NodeSpec> = (0..4)
        .map(|i| NodeSpec::worker(format!("cpu-{i}"), Resources::new(16_000, 32_000, 0)))
        .collect();
    for i in 0..4 {
        nodes.push(
            NodeSpec::worker(format!("gpu-{i}"), Resources::new(16_000, 32_000, 4))
                .label("accel", "gpu"),
        );
    }
    nodes.push(NodeSpec::worker("vnode-slurm", Resources::cpu(128_000)).virtual_node("slurm-main"));
    Arc::new(Cluster::new(nodes, 0))
}

fn open_journal(store: &str) -> Result<Arc<Journal>, String> {
    let storage = LocalStorage::new(store).map_err(|e| format!("opening store '{store}': {e}"))?;
    Ok(Arc::new(Journal::open(Arc::new(storage))?))
}

fn cmd_workflows() {
    println!("built-in workflows (paper §3 + demos):");
    for (name, desc) in WORKFLOWS {
        println!("  {name:<16} {desc}");
    }
}

/// `dflow lint`: run every analyzer pass over the named built-in
/// workflows (all of them by default) against the same demo cluster +
/// local executor `dflow submit` would use — without executing anything.
/// Errors (or warnings under `--deny-warnings`) exit nonzero.
fn cmd_lint(names: &[String], json: bool, deny_warnings: bool) -> Result<(), String> {
    let targets: Vec<String> = if names.is_empty() {
        WORKFLOWS.iter().map(|(n, _)| n.to_string()).collect()
    } else {
        names.to_vec()
    };
    let cluster = demo_cluster();
    let ctx = dflow::analysis::AnalysisContext {
        placer: None,
        cluster: Some(&cluster),
        executors: Some(vec!["local".to_string()]),
        service: Some(dflow::analysis::ServiceHints {
            max_live_runs: ServiceConfig::default().max_live_runs,
        }),
    };
    let (mut errors, mut warnings) = (0usize, 0usize);
    let mut rows: Vec<dflow::jsonx::Json> = Vec::new();
    for name in &targets {
        let wf = build(name, 0)
            .ok_or_else(|| format!("unknown workflow '{name}' — see `dflow workflows`"))?;
        let report = dflow::analysis::Report::new(dflow::analysis::analyze_with(&wf, &ctx));
        errors += report.errors().count();
        warnings += report.warnings().count();
        if json {
            rows.push(dflow::jsonx::Json::obj(vec![
                ("workflow", dflow::jsonx::Json::s(name.clone())),
                ("diagnostics", report.to_json()),
            ]));
        } else if report.diagnostics.is_empty() {
            println!("{name}: ok");
        } else {
            println!("{name}:");
            for d in &report.diagnostics {
                println!("  {}", d.render());
                println!("      help: {}", d.help);
            }
        }
    }
    if json {
        println!("{}", dflow::jsonx::Json::Arr(rows).to_string_pretty());
    } else {
        println!(
            "linted {} workflow(s) against the demo cluster: {errors} error(s), {warnings} warning(s)"
            , targets.len()
        );
    }
    if errors > 0 {
        return Err(format!("lint found {errors} error(s)"));
    }
    if deny_warnings && warnings > 0 {
        return Err(format!("lint found {warnings} warning(s) and --deny-warnings is set"));
    }
    Ok(())
}

/// One-line human summary of the event payload: the failure/cancel
/// message head, the backend a placement landed on, who evicted whom,
/// lint warning counts, log-flush pointers. Empty for events whose kind
/// plus path already says everything.
fn event_detail(ev: &dflow::journal::JournalEvent) -> String {
    use dflow::journal::JournalEvent as E;
    let s = match ev {
        E::RunFailed { message }
        | E::NodeFailed { message, .. }
        | E::NodeRetrying { message, .. } => message.clone(),
        E::RunCancelled { reason } | E::NodeCancelled { reason, .. } => reason.clone(),
        E::RunLinted { warnings } => format!("{} lint warning(s)", warnings.len()),
        E::NodePlaced { backend, .. } => format!("-> {backend}"),
        E::NodeEvicted { by, .. } => format!("evicted by {by}"),
        E::NodeFailedOver { backend, message, .. } => format!("from {backend}: {message}"),
        E::NodeLogs { key, bytes, truncated, .. } => {
            format!("{bytes}B -> {key}{}", if *truncated { " (truncated)" } else { "" })
        }
        _ => String::new(),
    };
    // first line only (failure messages carry multi-line log tails), capped
    let first = s.lines().next().unwrap_or("");
    if first.chars().count() > 72 {
        let head: String = first.chars().take(71).collect();
        format!("{head}…")
    } else {
        first.to_string()
    }
}

fn event_line(rec: &dflow::journal::Recorded) -> String {
    let ev = &rec.event;
    let path = ev.path().unwrap_or("");
    let detail = event_detail(ev);
    if detail.is_empty() {
        format!("{:>13}  {:<19} {}", rec.at_ms, ev.kind(), path)
    } else {
        format!("{:>13}  {:<19} {:<24} {detail}", rec.at_ms, ev.kind(), path)
    }
}

/// One in-process service over the shared store: demo cluster + batched
/// journal appender (+ the PJRT runtime when `name` needs it). Shared by
/// `submit` and `retry` so their engine/service setup cannot drift.
fn start_service(name: &str, store: &str) -> Result<(WorkflowService, Arc<Journal>), String> {
    let journal = open_journal(store)?;
    let mut builder = Engine::builder()
        .cluster(demo_cluster())
        .journal_appender(Appender::spawn(Arc::clone(&journal)));
    if needs_runtime(name) {
        let rt = Runtime::global()
            .ok_or("artifacts/ not built — run `make artifacts` first (or use demo-*)")?;
        builder = builder.runtime(rt);
    }
    let engine = Arc::new(builder.build());
    let config = ServiceConfig {
        // quick ticks so a cross-process `dflow cancel` lands promptly
        maintenance_interval: Duration::from_millis(250),
        ..ServiceConfig::default()
    };
    let service = WorkflowService::start(engine, config)?;
    Ok((service, journal))
}

fn cmd_submit(name: &str, seed: i64, tenant: &str, store: &str) -> Result<(), String> {
    let wf = build(name, seed)
        .ok_or_else(|| format!("unknown workflow '{name}' — see `dflow workflows`"))?;
    let (service, _journal) = start_service(name, store)?;
    let run_id = service.submit(tenant, wf)?;
    println!("submitted '{name}' (seed {seed}) as run {run_id} for tenant '{tenant}'");
    println!("  store: {store}  (watch:  dflow watch {run_id} --store {store})");
    println!("  cancel from another terminal:  dflow cancel {run_id} --store {store}");
    let t0 = std::time::Instant::now();
    let phase = service
        .watch(run_id)
        .follow(Duration::from_millis(100), |rec| println!("  {}", event_line(rec)))?;
    println!("phase={phase:?} in {:.2}s", t0.elapsed().as_secs_f64());
    let rec = service.registry().get_run(run_id)?;
    println!(
        "  {} nodes — {} succeeded, {} failed, {} reused; {} events journaled",
        rec.nodes.len(),
        rec.count_phase(dflow::engine::NodePhase::Succeeded),
        rec.count_phase(dflow::engine::NodePhase::Failed),
        rec.count_phase(dflow::engine::NodePhase::Reused),
        rec.events,
    );
    Ok(())
}

fn cmd_list(store: &str, json: bool) -> Result<(), String> {
    let journal = open_journal(store)?;
    let registry = RunRegistry::new(journal);
    if json {
        println!("{}", registry.list_runs_json()?.to_string_pretty());
        return Ok(());
    }
    let runs = registry.list_runs()?;
    if runs.is_empty() {
        println!("no journaled runs under '{store}'");
        return Ok(());
    }
    println!(
        "{:<22} {:<18} {:<10} {:>6} {:>5} {:>5} {:>7} {:>7}",
        "RUN", "WORKFLOW", "PHASE", "NODES", "OK", "FAIL", "REUSED", "EVENTS"
    );
    for r in runs {
        println!(
            "{:<22} {:<18} {:<10} {:>6} {:>5} {:>5} {:>7} {:>7}  {}",
            r.run_id,
            r.workflow,
            format!("{:?}", r.phase),
            r.nodes,
            r.succeeded,
            r.failed,
            r.reused,
            r.events,
            r.message,
        );
    }
    Ok(())
}

fn parse_run_id(s: &str) -> Result<u64, String> {
    s.parse::<u64>().map_err(|_| format!("'{s}' is not a run id (u64)"))
}

fn cmd_get(arg: &str, store: &str, json: bool) -> Result<(), String> {
    if let Ok(run_id) = arg.parse::<u64>() {
        let journal = open_journal(store)?;
        let rec = journal.replay(run_id)?;
        if json {
            println!("{}", rec.to_json().to_string_pretty());
            return Ok(());
        }
        println!("run {run_id} — workflow '{}' — {:?}", rec.workflow, rec.phase);
        if rec.resubmissions > 0 {
            println!("  resubmissions: {}", rec.resubmissions);
        }
        if rec.failovers > 0 || rec.evictions > 0 {
            println!("  {} failover(s), {} eviction(s)", rec.failovers, rec.evictions);
        }
        if !rec.lint.is_empty() {
            println!("  {} lint warning(s) at admission:", rec.lint.len());
            for w in &rec.lint {
                println!("    {w}");
            }
        }
        if !rec.message.is_empty() {
            // terminal failure message; carries the captured log tail
            for line in rec.message.lines() {
                println!("  {line}");
            }
        }
        for (path, n) in &rec.nodes {
            println!(
                "  {:<9} {:<32} attempts={}{}{}",
                format!("{:?}", n.phase),
                path,
                n.attempts,
                n.backend.as_deref().map(|b| format!(" on {b}")).unwrap_or_default(),
                n.key.as_deref().map(|k| format!(" key={k}")).unwrap_or_default(),
            );
            // failure forensics: the journaled message ends with the last
            // captured log lines of the failing attempt — show them inline
            if matches!(n.phase, dflow::engine::NodePhase::Failed) && !n.message.is_empty() {
                for line in n.message.lines() {
                    println!("      {line}");
                }
            }
        }
        println!(
            "  {} event(s) journaled{}  (logs: dflow logs {run_id} --store {store})",
            rec.events,
            if rec.torn_tail { ", torn tail truncated" } else { "" },
        );
        return Ok(());
    }
    // legacy: pretty-print a saved status JSON file
    let text = std::fs::read_to_string(arg).map_err(|e| e.to_string())?;
    let j = dflow::jsonx::Json::parse(&text).map_err(|e| e.to_string())?;
    println!(
        "workflow {} — phase {}",
        j.get("workflow").and_then(|v| v.as_str()).unwrap_or("?"),
        j.get("phase").and_then(|v| v.as_str()).unwrap_or("?")
    );
    if let Some(nodes) = j.get("nodes").and_then(|n| n.as_arr()) {
        for n in nodes {
            println!(
                "  {:<9} {:<60} retries={} {}",
                n.get("phase").and_then(|v| v.as_str()).unwrap_or("?"),
                n.get("path").and_then(|v| v.as_str()).unwrap_or("?"),
                n.get("retries").and_then(|v| v.as_i64()).unwrap_or(0),
                n.get("key")
                    .and_then(|v| v.as_str())
                    .map(|k| format!("key={k}"))
                    .unwrap_or_default(),
            );
        }
    }
    Ok(())
}

fn cmd_timeline(run_id: u64, path: Option<&str>, store: &str, json: bool) -> Result<(), String> {
    let journal = open_journal(store)?;
    let registry = RunRegistry::new(Arc::clone(&journal));
    if json {
        println!("{}", registry.timeline_json(run_id, path)?.to_string_pretty());
        return Ok(());
    }
    let rec = journal.replay(run_id)?;
    println!(
        "run {run_id} — '{}' — {:?}; {} failover(s), {} eviction(s), {} lint warning(s)",
        rec.workflow,
        rec.phase,
        rec.failovers,
        rec.evictions,
        rec.lint.len(),
    );
    for w in &rec.lint {
        println!("  lint: {w}");
    }
    for r in registry.node_timeline(run_id, path)? {
        println!("{}", event_line(&r));
    }
    Ok(())
}

fn cmd_watch(run_id: u64, store: &str) -> Result<(), String> {
    let journal = open_journal(store)?;
    // follow() waits forever for a stream to appear (correct for a run a
    // live service is about to start, wrong for a typo'd id): require the
    // run to exist in this store
    if !journal.run_ids()?.contains(&run_id) {
        return Err(format!(
            "run {run_id} has no journal records under '{store}' — check the id (`dflow \
             list`) and the --store directory"
        ));
    }
    println!("watching run {run_id} (ctrl-c to stop; stream is durable either way)");
    let phase = RunWatch::new(journal, run_id)
        .follow(Duration::from_millis(250), |rec| println!("{}", event_line(rec)))?;
    println!("run {run_id} closed: {phase:?}");
    Ok(())
}

/// Print one decoded log line (follow mode), honoring the level floor.
fn print_follow_line(
    path: &str,
    attempt: u32,
    l: &dflow::obs::LogLine,
    min: Option<dflow::obs::LogLevel>,
    json: bool,
) {
    if min.is_some_and(|m| l.level < m) {
        return;
    }
    if json {
        println!(
            "{}",
            dflow::jsonx::Json::obj(vec![
                ("path", dflow::jsonx::Json::s(path)),
                ("attempt", dflow::jsonx::Json::n(attempt as f64)),
                ("seq", dflow::jsonx::Json::n(l.seq as f64)),
                ("ts_ms", dflow::jsonx::Json::n(l.ts_ms as f64)),
                ("level", dflow::jsonx::Json::s(l.level.as_str())),
                ("msg", dflow::jsonx::Json::s(l.msg.clone())),
            ])
            .to_string_compact()
        );
    } else {
        println!("[{path} a{attempt}] {}", dflow::obs::logs::render_line(l));
    }
}

/// `dflow logs`: the flight recorder's read side. Post-hoc it folds the
/// run's journaled `NodeLogs` pointers into readable streams (works
/// cross-process and after compaction — the pointers are carried into
/// snapshots). With `--follow` it tails the journal like `dflow watch`,
/// downloading each chunk the moment its pointer lands, so a second
/// terminal sees OP output near-live.
fn cmd_logs(
    run_id: u64,
    path: Option<&str>,
    attempt: Option<u32>,
    follow: bool,
    min_level: Option<dflow::obs::LogLevel>,
    store: &str,
    json: bool,
) -> Result<(), String> {
    let journal = open_journal(store)?;
    if follow {
        if !journal.run_ids()?.contains(&run_id) {
            return Err(format!(
                "run {run_id} has no journal records under '{store}' — check the id (`dflow \
                 list`) and the --store directory"
            ));
        }
        if !json {
            println!("following run {run_id} logs (ctrl-c to stop; chunks are durable either way)");
        }
        let storage = Arc::clone(journal.storage());
        let phase = RunWatch::new(Arc::clone(&journal), run_id).follow(
            Duration::from_millis(250),
            |rec| {
                let dflow::journal::JournalEvent::NodeLogs { path: p, attempt: a, key, .. } =
                    &rec.event
                else {
                    return;
                };
                if path.is_some_and(|want| want != p.as_str())
                    || attempt.is_some_and(|want| want != *a)
                {
                    return;
                }
                // chunk may be gone if `compact --purge-logs` raced us
                let Ok(bytes) = storage.download(key) else { return };
                for l in dflow::obs::logs::decode(&bytes) {
                    print_follow_line(p, *a, &l, min_level, json);
                }
            },
        )?;
        if !json {
            println!("run {run_id} closed: {phase:?}");
        }
        return Ok(());
    }
    let registry = RunRegistry::new(journal);
    let chunks = registry.logs(run_id, path, attempt)?;
    if json {
        let arr = dflow::jsonx::Json::Arr(chunks.iter().map(|c| c.to_json()).collect());
        println!("{}", arr.to_string_pretty());
        return Ok(());
    }
    if chunks.is_empty() {
        println!(
            "run {run_id} journaled no log chunks — the OPs logged nothing, or the engine \
             ran with log capture off"
        );
        return Ok(());
    }
    for c in &chunks {
        println!(
            "== {} a{} — {} byte(s){}",
            c.path,
            c.attempt,
            c.bytes,
            if c.truncated { ", ring overflowed (oldest lines dropped)" } else { "" },
        );
        if let Some(e) = &c.error {
            println!("   chunk unreadable ({e}) — purged by `dflow compact --purge-logs`?");
        }
        for l in &c.lines {
            if min_level.is_some_and(|m| l.level < m) {
                continue;
            }
            println!("{}", dflow::obs::logs::render_line(l));
        }
    }
    Ok(())
}

fn cmd_cancel(run_id: u64, reason: &str, store: &str) -> Result<(), String> {
    let journal = open_journal(store)?;
    journal.request_cancel(run_id, reason)?;
    println!(
        "cancel marker written for run {run_id} (reason: {reason}); a live service \
         applies it on its next maintenance tick"
    );
    Ok(())
}

fn cmd_retry(name: &str, run_id: u64, seed: i64, tenant: &str, store: &str) -> Result<(), String> {
    let wf = build(name, seed)
        .ok_or_else(|| format!("unknown workflow '{name}' — see `dflow workflows`"))?;
    let (service, journal) = start_service(name, store)?;
    // the stream already holds a terminal event from the previous attempt,
    // so a plain follow() would return immediately — wait for the
    // resubmission generation to close instead
    let base = journal.replay(run_id)?.resubmissions;
    let id = service.retry(tenant, wf, run_id)?;
    println!("retrying run {id} ('{name}'): journaled successes are reused");
    let mut watch = service.watch(id);
    loop {
        for rec in watch.poll()? {
            println!("  {}", event_line(&rec));
        }
        let rec = journal.replay(run_id)?;
        if rec.resubmissions > base && !matches!(rec.phase, dflow::engine::RunPhase::Running) {
            println!("phase={:?}", rec.phase);
            break;
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    Ok(())
}

fn cmd_compact(arg: &str, purge_logs: bool, store: &str) -> Result<(), String> {
    let journal = open_journal(store)?;
    let ids: Vec<u64> = if arg == "--all" {
        let registry = RunRegistry::new(Arc::clone(&journal));
        registry
            .list_runs()?
            .into_iter()
            .filter(|r| !matches!(r.phase, dflow::engine::RunPhase::Running))
            .map(|r| r.run_id)
            .collect()
    } else {
        vec![parse_run_id(arg)?]
    };
    for id in ids {
        match journal.compact(id) {
            Ok(report) => println!(
                "run {id}: folded {} events, removed {} segment object(s)",
                report.events_folded, report.segments_removed
            ),
            Err(e) => println!("run {id}: not compacted ({e})"),
        }
        if purge_logs {
            // log retention is deliberate: chunks outlive compaction until
            // the operator asks for them to go
            match journal.purge_logs(id) {
                Ok(0) => {}
                Ok(n) => println!("run {id}: purged {n} captured log object(s)"),
                Err(e) => println!("run {id}: logs not purged ({e})"),
            }
        }
    }
    Ok(())
}

/// `dflow profile`: fold a run's journaled telemetry spans into a
/// per-step phase breakdown plus the critical path — works on any process'
/// runs sharing the store, live or closed, compacted or not.
fn cmd_profile(run_id: u64, store: &str, json: bool) -> Result<(), String> {
    let journal = open_journal(store)?;
    let registry = RunRegistry::new(journal);
    let profile = registry.profile(run_id)?;
    if json {
        println!("{}", profile.to_json().to_string_pretty());
    } else {
        print!("{}", profile.render_text());
    }
    Ok(())
}

/// `dflow top`: fleet view over the shared store — every run whose journal
/// stream is still open, with node-phase progress and stream age.
/// Cross-process by construction: it reads the same durable registry any
/// live service is appending to.
fn cmd_top(store: &str, json: bool) -> Result<(), String> {
    let journal = open_journal(store)?;
    let registry = RunRegistry::new(Arc::clone(&journal));
    let all = registry.list_runs()?;
    let live: Vec<_> = all
        .iter()
        .filter(|r| matches!(r.phase, dflow::engine::RunPhase::Running))
        .collect();
    let now = dflow::util::epoch_ms();
    let mut rows: Vec<dflow::jsonx::Json> = Vec::new();
    if !json {
        if live.is_empty() {
            println!("no live runs under '{store}' ({} journaled)", all.len());
            return Ok(());
        }
        println!(
            "{:<22} {:<18} {:>6} {:>5} {:>5} {:>8} {:>10}",
            "RUN", "WORKFLOW", "NODES", "OK", "RUN", "FAIL", "LAST-EVENT"
        );
    }
    for r in &live {
        let rec = journal.replay(r.run_id)?;
        let running = rec.count_phase(dflow::engine::NodePhase::Running);
        let (records, _torn) = journal.events(r.run_id)?;
        let last_ms = records.last().map(|x| x.at_ms).unwrap_or(now);
        let age_ms = now.saturating_sub(last_ms);
        if json {
            rows.push(dflow::jsonx::Json::obj(vec![
                ("run_id", dflow::jsonx::Json::n(r.run_id as f64)),
                ("workflow", dflow::jsonx::Json::s(r.workflow.clone())),
                ("nodes", dflow::jsonx::Json::n(r.nodes as f64)),
                ("succeeded", dflow::jsonx::Json::n(r.succeeded as f64)),
                ("running", dflow::jsonx::Json::n(running as f64)),
                ("failed", dflow::jsonx::Json::n(r.failed as f64)),
                ("last_event_ms_ago", dflow::jsonx::Json::n(age_ms as f64)),
            ]));
        } else {
            println!(
                "{:<22} {:<18} {:>6} {:>5} {:>5} {:>8} {:>8.1}s",
                r.run_id,
                r.workflow,
                r.nodes,
                r.succeeded,
                running,
                r.failed,
                age_ms as f64 / 1e3,
            );
        }
    }
    if json {
        println!(
            "{}",
            dflow::jsonx::Json::obj(vec![
                ("live", dflow::jsonx::Json::Arr(rows)),
                ("journaled", dflow::jsonx::Json::n(all.len() as f64)),
            ])
            .to_string_pretty()
        );
    } else {
        println!("{} live of {} journaled run(s)", live.len(), all.len());
    }
    Ok(())
}

/// `dflow metrics`: the scrape surface. Starts an in-process service over
/// the store — optionally running one workflow first so counters and
/// latency summaries are populated — and prints the full metrics document
/// as Prometheus text (default) or JSON (`--json`).
fn cmd_metrics(
    name: Option<&str>,
    seed: i64,
    tenant: &str,
    store: &str,
    json: bool,
) -> Result<(), String> {
    let (service, _journal) = start_service(name.unwrap_or("demo-fanout"), store)?;
    if let Some(name) = name {
        let wf = build(name, seed)
            .ok_or_else(|| format!("unknown workflow '{name}' — see `dflow workflows`"))?;
        let run_id = service.submit(tenant, wf)?;
        eprintln!("running '{name}' as run {run_id} to populate the export...");
        if !service.wait_idle(Duration::from_secs(600)) {
            return Err(format!("run {run_id} did not finish within 600s"));
        }
    }
    let doc = service.export_metrics();
    if json {
        println!("{}", doc.to_json().to_string_pretty());
    } else {
        print!("{}", doc.to_prometheus());
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let rt = Runtime::global()
        .ok_or("artifacts/ not built — run `make artifacts` first".to_string())?;
    println!("AOT artifacts:");
    for name in rt.available() {
        println!("  {name}");
    }
    // force-compile one to show timing
    let x = dflow::runtime::Tensor::new(
        vec![64, 3],
        dflow::science::lj::lattice(64, 1.2, 0.05, 0),
    )
    .unwrap();
    let out = rt.exec("lj_ef", &[x]).map_err(|e| e.to_string())?;
    println!("lj_ef smoke: E = {:.4}", out[0].item());
    for (name, ms) in rt.compile_times() {
        println!("  compile {name}: {ms:.1} ms");
    }
    Ok(())
}

/// Remove `--flag value` from `args`, returning the value.
fn take_flag_value(args: &mut Vec<String>, flag: &str) -> Option<String> {
    let i = args.iter().position(|a| a == flag)?;
    if i + 1 < args.len() {
        let v = args.remove(i + 1);
        args.remove(i);
        Some(v)
    } else {
        args.remove(i);
        None
    }
}

/// Remove a bare `--flag` from `args`, returning whether it was present.
fn take_flag(args: &mut Vec<String>, flag: &str) -> bool {
    if let Some(i) = args.iter().position(|a| a == flag) {
        args.remove(i);
        true
    } else {
        false
    }
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let store = take_flag_value(&mut args, "--store")
        .or_else(|| std::env::var("DFLOW_STORE").ok())
        .unwrap_or_else(|| ".dflow-store".to_string());
    let tenant =
        take_flag_value(&mut args, "--tenant").unwrap_or_else(|| "default".to_string());
    let json = take_flag(&mut args, "--json");
    // `--prom` is metrics' default output; accepted so scripts can be explicit
    let _prom = take_flag(&mut args, "--prom");
    let deny_warnings = take_flag(&mut args, "--deny-warnings");
    let follow = take_flag(&mut args, "--follow");
    let attempt = take_flag_value(&mut args, "--attempt").and_then(|s| s.parse::<u32>().ok());
    let level = take_flag_value(&mut args, "--level");
    let purge_logs = take_flag(&mut args, "--purge-logs");
    let arg = |i: usize| args.get(i).map(String::as_str);
    let result = match arg(0) {
        Some("workflows") | None => {
            cmd_workflows();
            Ok(())
        }
        Some("lint") => cmd_lint(&args[1..], json, deny_warnings),
        Some("list") => cmd_list(&store, json),
        Some("submit") => {
            let name = arg(1).unwrap_or_default().to_string();
            let seed = arg(2).and_then(|s| s.parse().ok()).unwrap_or(0);
            cmd_submit(&name, seed, &tenant, &store)
        }
        Some("get") => cmd_get(arg(1).unwrap_or(""), &store, json),
        Some("timeline") => match arg(1).map(parse_run_id) {
            Some(Ok(id)) => cmd_timeline(id, arg(2), &store, json),
            Some(Err(e)) => Err(e),
            None => Err("usage: dflow timeline <run_id> [node-path]".to_string()),
        },
        Some("watch") => match arg(1).map(parse_run_id) {
            Some(Ok(id)) => cmd_watch(id, &store),
            Some(Err(e)) => Err(e),
            None => Err("usage: dflow watch <run_id>".to_string()),
        },
        Some("logs") => match arg(1).map(parse_run_id) {
            Some(Ok(id)) => {
                match level.as_deref().map(|s| {
                    dflow::obs::LogLevel::parse(s)
                        .ok_or_else(|| format!("unknown --level '{s}' (debug|info|warn|error)"))
                }) {
                    Some(Err(e)) => Err(e),
                    Some(Ok(l)) => cmd_logs(id, arg(2), attempt, follow, Some(l), &store, json),
                    None => cmd_logs(id, arg(2), attempt, follow, None, &store, json),
                }
            }
            Some(Err(e)) => Err(e),
            None => Err(
                "usage: dflow logs <run_id> [node-path] [--attempt N] [--follow] [--level L] \
                 [--json]"
                    .to_string(),
            ),
        },
        Some("cancel") => match arg(1).map(parse_run_id) {
            Some(Ok(id)) => {
                let reason = if args.len() > 2 { args[2..].join(" ") } else {
                    "cancelled via dflow CLI".to_string()
                };
                cmd_cancel(id, &reason, &store)
            }
            Some(Err(e)) => Err(e),
            None => Err("usage: dflow cancel <run_id> [reason]".to_string()),
        },
        Some("retry") => {
            let name = arg(1).unwrap_or_default().to_string();
            match arg(2).map(parse_run_id) {
                Some(Ok(id)) => {
                    let seed = arg(3).and_then(|s| s.parse().ok()).unwrap_or(0);
                    cmd_retry(&name, id, seed, &tenant, &store)
                }
                Some(Err(e)) => Err(e),
                None => Err("usage: dflow retry <workflow> <run_id> [seed]".to_string()),
            }
        }
        Some("compact") => match arg(1) {
            Some(a) => cmd_compact(a, purge_logs, &store),
            None => Err("usage: dflow compact <run_id>|--all [--purge-logs]".to_string()),
        },
        Some("profile") => match arg(1).map(parse_run_id) {
            Some(Ok(id)) => cmd_profile(id, &store, json),
            Some(Err(e)) => Err(e),
            None => Err("usage: dflow profile <run_id> [--json]".to_string()),
        },
        Some("top") => cmd_top(&store, json),
        Some("metrics") => {
            let name = arg(1).map(str::to_string);
            let seed = arg(2).and_then(|s| s.parse().ok()).unwrap_or(0);
            cmd_metrics(name.as_deref(), seed, &tenant, &store, json)
        }
        Some("artifacts") => cmd_artifacts(),
        Some("cluster") => {
            println!("{}", demo_cluster().to_json().to_string_pretty());
            Ok(())
        }
        Some(other) => Err(format!(
            "unknown command '{other}' (try: workflows, lint, submit, list, get, timeline, \
             watch, logs, cancel, retry, compact, profile, top, metrics, artifacts, cluster)"
        )),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
