//! `dflow` CLI: submit, inspect and watch workflow runs (the library-form
//! analogue of Dflow's command-line tools + web UI status views).
//!
//! ```text
//! dflow list                      # built-in application workflows
//! dflow submit <name> [seed]     # run one; writes status JSON to ./.dflow-runs/
//! dflow get <status.json>        # pretty-print a saved run status
//! dflow artifacts                # AOT artifact inventory + compile times
//! dflow cluster                  # demo cluster topology as JSON
//! ```

use std::sync::Arc;

use dflow::apps::{apex, deepks, fpop, rid, tesla, vsw};
use dflow::cluster::{Cluster, NodeSpec, Resources};
use dflow::core::Workflow;
use dflow::engine::Engine;
use dflow::runtime::Runtime;

const WORKFLOWS: &[(&str, &str)] = &[
    ("fpop-eos", "FPOP EOS flow (paper Fig. 3)"),
    ("apex-relaxation", "APEX relaxation job (Fig. 4)"),
    ("apex-joint", "APEX joint relaxation+property job (Fig. 4)"),
    ("rid", "Rid-kit reinforced-dynamics loop (Fig. 5)"),
    ("deepks", "DeePKS SCF⇄train loop (Fig. 6)"),
    ("vsw", "Virtual screening funnel (Fig. 7)"),
    ("tesla", "TESLA concurrent-learning loop (Fig. 8)"),
];

fn build(name: &str, seed: i64) -> Option<Workflow> {
    let scales = [0.85, 0.9, 0.95, 1.0, 1.05, 1.1, 1.15];
    Some(match name {
        "fpop-eos" => fpop::eos_workflow(seed, &scales, 2),
        "apex-relaxation" => apex::relaxation_workflow(seed),
        "apex-joint" => apex::joint_workflow(seed, &scales),
        "rid" => rid::workflow(&rid::RidConfig::default(), seed),
        "deepks" => deepks::workflow(&deepks::DeepksConfig::default()),
        "vsw" => vsw::workflow(&vsw::VswConfig::default(), seed),
        "tesla" => tesla::workflow(&tesla::TeslaConfig::default(), seed),
        _ => return None,
    })
}

fn demo_cluster() -> Arc<Cluster> {
    let mut nodes: Vec<NodeSpec> = (0..4)
        .map(|i| NodeSpec::worker(format!("cpu-{i}"), Resources::new(16_000, 32_000, 0)))
        .collect();
    for i in 0..4 {
        nodes.push(
            NodeSpec::worker(format!("gpu-{i}"), Resources::new(16_000, 32_000, 4))
                .label("accel", "gpu"),
        );
    }
    nodes.push(NodeSpec::worker("vnode-slurm", Resources::cpu(128_000)).virtual_node("slurm-main"));
    Arc::new(Cluster::new(nodes, 0))
}

fn cmd_list() {
    println!("built-in application workflows (paper §3):");
    for (name, desc) in WORKFLOWS {
        println!("  {name:<16} {desc}");
    }
}

fn cmd_submit(name: &str, seed: i64) -> Result<(), String> {
    let wf = build(name, seed)
        .ok_or_else(|| format!("unknown workflow '{name}' — see `dflow list`"))?;
    let rt = Runtime::global()
        .ok_or("artifacts/ not built — run `make artifacts` first".to_string())?;
    let engine = Engine::builder().runtime(rt).cluster(demo_cluster()).build();
    println!("submitting '{name}' (seed {seed}) ...");
    let t0 = std::time::Instant::now();
    let result = engine.run(&wf)?;
    let dt = t0.elapsed();
    let status = result.run.to_json().to_string_pretty();
    std::fs::create_dir_all(".dflow-runs").map_err(|e| e.to_string())?;
    let path = format!(".dflow-runs/{}-{}.json", name, result.run.id);
    std::fs::write(&path, &status).map_err(|e| e.to_string())?;
    println!(
        "phase={:?} in {:.2}s — {} nodes, {} succeeded, {} failed, {} reused",
        result.run.phase(),
        dt.as_secs_f64(),
        result.run.nodes().len(),
        result.run.metrics.steps_succeeded.get(),
        result.run.metrics.steps_failed.get(),
        result.run.metrics.steps_reused.get(),
    );
    for (k, v) in &result.outputs.params {
        println!("  output {k} = {}", v.display());
    }
    if let Some(e) = &result.error {
        println!("  error: {e}");
    }
    println!("status written to {path}");
    Ok(())
}

fn cmd_get(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let j = dflow::jsonx::Json::parse(&text).map_err(|e| e.to_string())?;
    println!(
        "workflow {} — phase {}",
        j.get("workflow").and_then(|v| v.as_str()).unwrap_or("?"),
        j.get("phase").and_then(|v| v.as_str()).unwrap_or("?")
    );
    if let Some(nodes) = j.get("nodes").and_then(|n| n.as_arr()) {
        for n in nodes {
            println!(
                "  {:<9} {:<60} retries={} {}",
                n.get("phase").and_then(|v| v.as_str()).unwrap_or("?"),
                n.get("path").and_then(|v| v.as_str()).unwrap_or("?"),
                n.get("retries").and_then(|v| v.as_i64()).unwrap_or(0),
                n.get("key")
                    .and_then(|v| v.as_str())
                    .map(|k| format!("key={k}"))
                    .unwrap_or_default(),
            );
        }
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let rt = Runtime::global()
        .ok_or("artifacts/ not built — run `make artifacts` first".to_string())?;
    println!("AOT artifacts:");
    for name in rt.available() {
        println!("  {name}");
    }
    // force-compile one to show timing
    let x = dflow::runtime::Tensor::new(
        vec![64, 3],
        dflow::science::lj::lattice(64, 1.2, 0.05, 0),
    )
    .unwrap();
    let out = rt.exec("lj_ef", &[x]).map_err(|e| e.to_string())?;
    println!("lj_ef smoke: E = {:.4}", out[0].item());
    for (name, ms) in rt.compile_times() {
        println!("  compile {name}: {ms:.1} ms");
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("list") | None => {
            cmd_list();
            Ok(())
        }
        Some("submit") => {
            let name = args.get(1).cloned().unwrap_or_default();
            let seed = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0);
            cmd_submit(&name, seed)
        }
        Some("get") => cmd_get(args.get(1).map(String::as_str).unwrap_or("")),
        Some("artifacts") => cmd_artifacts(),
        Some("cluster") => {
            println!("{}", demo_cluster().to_json().to_string_pretty());
            Ok(())
        }
        Some(other) => Err(format!(
            "unknown command '{other}' (try: list, submit, get, artifacts, cluster)"
        )),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
