//! Workflow service control plane: a multi-run daemon over one engine.
//!
//! The paper's Dflow runs as a long-lived, shared deployment (an Argo
//! server plus registry) that many scientists submit to concurrently —
//! dozens of projects, thousands of nodes per workflow, one pool of
//! backends. Before this module the reproduction was a one-shot library
//! call: one `Engine::run` per process, no admission control, no live run
//! lifecycle, no tenancy. [`WorkflowService`] is the layer that turns the
//! four subsystems (engine, placement, storage, journal) into one system:
//!
//! * **Admission control.** Submissions enter a **bounded** queue
//!   ([`ServiceConfig::queue_cap`]; a full queue rejects with a clear
//!   error instead of buffering without limit). A dispatcher starts queued
//!   runs only while the global live-run count is below
//!   [`ServiceConfig::max_live_runs`] and the submitting tenant is below
//!   its quota.
//! * **Fair-share ordering.** The dispatcher picks the next run from the
//!   admissible tenant with the fewest live runs (tiebreak: fewest runs
//!   ever started, then FIFO), so one tenant's 2000-slice fan-out cannot
//!   starve other tenants sharing the same backends — they interleave at
//!   the run level, and the engine's adaptive scheduler pool
//!   (`EngineConfig::adaptive_cap`) keeps one run's capacity waits from
//!   monopolizing pool workers below that.
//! * **Live run lifecycle.** [`WorkflowService::cancel`] propagates
//!   through the run's cancel tokens into in-flight OPs (pods/leases
//!   release when each OP actually stops — the timeout discipline) and
//!   journals `RunCancelled`; [`WorkflowService::retry`] re-queues a
//!   closed run **under the same run id** with every journaled success
//!   spliced in, so exactly the non-succeeded suffix re-executes;
//!   [`WorkflowService::watch`] tails the run's journal (the durable
//!   trace, pod/lease mirror events included) as a [`RunWatch`] stream.
//! * **Service-owned maintenance.** A maintenance tick drains durable
//!   cancel markers (`Journal::request_cancel` — the cross-process `dflow
//!   cancel`) and auto-compacts closed runs' journals
//!   ([`ServiceConfig::auto_compact`]), so long-lived deployments don't
//!   accrete one segment chain per run forever.
//! * **Per-tenant accounting.** [`ServiceMetrics`] counts submissions,
//!   rejections, starts, outcomes and peak live runs per tenant
//!   (`metrics::LabelCounters`) — the quota-enforcement evidence.
//!
//! Pair the service with an engine built via
//! `EngineBuilder::journal_appender` to also decouple journal writes from
//! the run hot path: events land in batches (one segment upload per
//! drained batch) instead of re-uploading the open segment per event.
//!
//! ```no_run
//! use std::sync::Arc;
//! use dflow::engine::{Backend, Engine};
//! use dflow::journal::{Appender, Journal};
//! use dflow::service::{ServiceConfig, WorkflowService};
//! use dflow::storage::MemStorage;
//!
//! let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
//! let engine = Arc::new(
//!     Engine::builder()
//!         .backend(Backend::local_slots("box", 4))
//!         .journal_appender(Appender::spawn(Arc::clone(&journal)))
//!         .build(),
//! );
//! let svc = WorkflowService::start(engine, ServiceConfig::default()).unwrap();
//! let run_id = svc.submit("alice", my_workflow()).unwrap();
//! svc.watch(run_id); // RunWatch: poll()/follow() the journal stream
//! # fn my_workflow() -> dflow::core::Workflow { unimplemented!() }
//! ```
//! (`no_run`: doctest binaries lack the xla rpath in this build image.)

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::core::Workflow;
use crate::engine::{
    Engine, NodePhase, Priority, ReusedStep, RunPhase, SubmitOptions, Submitted, WorkflowRun,
};
use crate::journal::{Journal, JournalEvent, Recorded, RunRegistry};
use crate::jsonx::Json;
use crate::metrics::{Counter, LabelCounters};
use crate::obs::{Histogram, MetricsDoc};

/// Control-plane configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Global cap on concurrently executing runs.
    pub max_live_runs: usize,
    /// Per-tenant cap on concurrently executing runs, unless overridden
    /// in [`ServiceConfig::tenant_quotas`].
    pub default_tenant_quota: usize,
    /// Per-tenant quota overrides.
    pub tenant_quotas: BTreeMap<String, usize>,
    /// Bound on queued (admitted but not yet started) submissions; a full
    /// queue rejects new submissions.
    pub queue_cap: usize,
    /// Cadence of the maintenance tick (cancel markers, auto-compaction).
    pub maintenance_interval: Duration,
    /// Auto-compact closed runs' journals on the maintenance tick.
    pub auto_compact: bool,
    /// How long after a run closes before it becomes a compaction
    /// candidate — post-terminal stragglers (cancelled OP attempts still
    /// mirroring pod releases into the journal from pool workers) must
    /// drain first, or a compact could delete the segment their cached
    /// writer is re-uploading.
    pub compaction_grace: Duration,
    /// Placement priority class for tenants without an override. Flows
    /// into every attempt's [`crate::engine::PlaceRequest`]: a
    /// [`Priority::High`] run's blocked placements preempt queued
    /// lower-priority placements, and the dispatcher starts
    /// higher-priority queue entries first.
    pub default_priority: Priority,
    /// Per-tenant priority overrides.
    pub tenant_priorities: BTreeMap<String, Priority>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_live_runs: 8,
            default_tenant_quota: 4,
            tenant_quotas: BTreeMap::new(),
            queue_cap: 256,
            maintenance_interval: Duration::from_millis(500),
            auto_compact: true,
            compaction_grace: Duration::from_secs(1),
            default_priority: Priority::default(),
            tenant_priorities: BTreeMap::new(),
        }
    }
}

impl ServiceConfig {
    /// Override one tenant's live-run quota.
    pub fn with_quota(mut self, tenant: &str, quota: usize) -> ServiceConfig {
        self.tenant_quotas.insert(tenant.to_string(), quota);
        self
    }

    /// Effective quota for a tenant (min 1 — a zero quota would deadlock
    /// that tenant's queue entries forever).
    pub fn quota_for(&self, tenant: &str) -> usize {
        self.tenant_quotas
            .get(tenant)
            .copied()
            .unwrap_or(self.default_tenant_quota)
            .max(1)
    }

    /// Override one tenant's priority class.
    pub fn with_priority(mut self, tenant: &str, priority: Priority) -> ServiceConfig {
        self.tenant_priorities.insert(tenant.to_string(), priority);
        self
    }

    /// Effective priority class for a tenant.
    pub fn priority_for(&self, tenant: &str) -> Priority {
        self.tenant_priorities.get(tenant).copied().unwrap_or(self.default_priority)
    }
}

/// Per-tenant control-plane counters (see module docs).
#[derive(Default)]
pub struct ServiceMetrics {
    /// Submissions accepted into the queue, per tenant.
    pub submitted: LabelCounters,
    /// Submissions rejected (queue full / draining), per tenant.
    pub rejected: LabelCounters,
    /// Runs started by the dispatcher, per tenant.
    pub started: LabelCounters,
    pub succeeded: LabelCounters,
    pub failed: LabelCounters,
    pub cancelled: LabelCounters,
    /// High-water mark of concurrently live runs, per tenant (the quota
    /// invariant: never exceeds `quota_for(tenant)`).
    pub live_peak: LabelCounters,
    /// Encoded log bytes the tenant's runs flushed to the `.logs/`
    /// namespace (flight recorder), folded in at reap.
    pub log_bytes: LabelCounters,
    /// Attempt log-buffer flushes per tenant, folded in at reap.
    pub log_flushes: LabelCounters,
    /// Closed-run journal compactions performed by the maintenance tick.
    pub compactions: Counter,
    /// Durable cancel markers picked up by the maintenance tick.
    pub cancel_requests: Counter,
    /// Admission-queue wait: submission accepted → dispatcher started the
    /// run. The control-plane half of end-to-end latency (the engine's
    /// spans cover everything after the start).
    pub queue_wait: Histogram,
    /// Run wall-clock: dispatcher start → reaper observed the close.
    pub run_duration: Histogram,
}

impl ServiceMetrics {
    /// JSON export (the `dflow` CLI's service-status surface).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", self.submitted.to_json()),
            ("rejected", self.rejected.to_json()),
            ("started", self.started.to_json()),
            ("succeeded", self.succeeded.to_json()),
            ("failed", self.failed.to_json()),
            ("cancelled", self.cancelled.to_json()),
            ("live_peak", self.live_peak.to_json()),
            ("log_bytes", self.log_bytes.to_json()),
            ("log_flushes", self.log_flushes.to_json()),
            ("compactions", Json::n(self.compactions.get() as f64)),
            ("cancel_requests", Json::n(self.cancel_requests.get() as f64)),
            ("queue_wait", self.queue_wait.summary().to_json()),
            ("run_duration", self.run_duration.summary().to_json()),
        ])
    }
}

/// One queued (admitted, not yet started) submission.
struct Pending {
    run_id: u64,
    tenant: String,
    wf: Workflow,
    reuse: Vec<ReusedStep>,
    resubmission: bool,
    priority: Priority,
    /// When the submission entered the queue (feeds
    /// [`ServiceMetrics::queue_wait`] at dispatch).
    queued_at: Instant,
}

/// One executing run.
struct LiveRun {
    tenant: String,
    run: Arc<WorkflowRun>,
    /// When the dispatcher started the run (feeds `dflow top`'s age column
    /// and [`ServiceMetrics::run_duration`] at reap).
    started_at: Instant,
}

struct SvcState {
    queue: VecDeque<Pending>,
    /// Picked from the queue but not yet in `live` (the dispatcher is
    /// between the pick and the engine submission). Keeps every admitted
    /// run visible to `cancel`/`wait_idle` at all times — without it, a
    /// run would briefly be in neither map and a cancel could miss it.
    starting: BTreeSet<u64>,
    live: BTreeMap<u64, LiveRun>,
    /// run id → when the reaper closed it. Auto-compaction waits out a
    /// grace period so post-terminal stragglers (late OP attempts
    /// journaling trace mirrors through a cached segment writer) cannot
    /// race a compact that deletes the segment under them.
    recently_closed: BTreeMap<u64, Instant>,
    /// tenant → currently live runs (reserved at pick time, released by
    /// the reaper, so the dispatcher can never over-admit a tenant).
    tenant_live: BTreeMap<String, usize>,
    /// tenant → runs ever started (fair-share tiebreak).
    tenant_started: BTreeMap<String, u64>,
    /// `(tenant, run_id)` in dispatch order (fair-share observability).
    start_log: Vec<(String, u64)>,
    queue_peak: usize,
    draining: bool,
    shutdown: bool,
}

struct SvcInner {
    engine: Arc<Engine>,
    journal: Arc<Journal>,
    config: ServiceConfig,
    metrics: ServiceMetrics,
    state: Mutex<SvcState>,
    /// Dispatcher/drain wakeups: submission, run completion, shutdown.
    cv: Condvar,
    /// Maintenance wakeups: shutdown only (ticks are timer-driven).
    tick_cv: Condvar,
    /// Closed runs that may still need compaction: every run this service
    /// closes (reaped, cancelled-while-queued, refused) plus a one-time
    /// startup scan of pre-existing journal history. Drives the
    /// compaction loop — ids leave the set once compacted (or verified
    /// snapshot-only) — so steady-state ticks never re-list the journal.
    compact_candidates: Mutex<BTreeSet<u64>>,
    /// Startup scan of `compact_candidates` performed?
    scanned: AtomicBool,
    /// Serializes retry enqueues against an in-flight compaction of the
    /// same run (lock order: gate → state, everywhere).
    compact_gate: Mutex<()>,
    /// Fault-injection hook ([`crate::check::chaos`]): fired once per
    /// maintenance tick, before the tick's work — an event boundary
    /// chaos plans count to schedule backend kills/cordons against the
    /// control plane's own cadence.
    chaos: OnceLock<crate::util::ChaosHook>,
}

impl SvcInner {
    /// Pick the next startable submission under global and per-tenant
    /// caps, fair-share ordered. Reserves the tenant-live slot before
    /// releasing the lock.
    fn pick_locked(&self, st: &mut SvcState) -> Option<Pending> {
        if st.live.len() >= self.config.max_live_runs {
            return None;
        }
        // admissible = tenant below quota; among those prefer the highest
        // priority class, then the tenant with the fewest live runs, then
        // fewest-ever-started, then FIFO
        let mut best: Option<(std::cmp::Reverse<Priority>, usize, u64, usize)> = None;
        for (idx, p) in st.queue.iter().enumerate() {
            let live = st.tenant_live.get(&p.tenant).copied().unwrap_or(0);
            if live >= self.config.quota_for(&p.tenant) {
                continue;
            }
            let started = st.tenant_started.get(&p.tenant).copied().unwrap_or(0);
            let cand = (std::cmp::Reverse(p.priority), live, started, idx);
            if best.map_or(true, |b| cand < b) {
                best = Some(cand);
            }
        }
        let (_, _, _, idx) = best?;
        let p = st.queue.remove(idx).expect("indexed queue entry vanished");
        let live = st.tenant_live.entry(p.tenant.clone()).or_insert(0);
        *live += 1;
        self.metrics.live_peak.record_max(&p.tenant, *live as u64);
        *st.tenant_started.entry(p.tenant.clone()).or_insert(0) += 1;
        // visible as "starting" until the engine submission lands in
        // `live` — cancel and wait_idle must never see a gap
        st.starting.insert(p.run_id);
        Some(p)
    }

    fn dispatch_loop(self: &Arc<SvcInner>) {
        loop {
            let pending = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if let Some(p) = self.pick_locked(&mut st) {
                        break p;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            };
            let tenant = pending.tenant.clone();
            let run_id = pending.run_id;
            let wf_name = pending.wf.name.clone();
            let resubmission = pending.resubmission;
            let queued_at = pending.queued_at;
            let opts = SubmitOptions {
                reuse: pending.reuse,
                run_id: Some(run_id),
                resubmission,
                priority: pending.priority,
            };
            match self.engine.submit_with_options(pending.wf, opts) {
                Ok(sub) => {
                    self.metrics.started.inc(&tenant);
                    self.metrics.queue_wait.observe(queued_at.elapsed());
                    let mut st = self.state.lock().unwrap();
                    st.starting.remove(&run_id);
                    st.live.insert(
                        run_id,
                        LiveRun {
                            tenant: tenant.clone(),
                            run: Arc::clone(&sub.run),
                            started_at: Instant::now(),
                        },
                    );
                    st.start_log.push((tenant.clone(), run_id));
                    drop(st);
                    self.cv.notify_all();
                    let inner = Arc::clone(self);
                    std::thread::Builder::new()
                        .name(format!("dflow-svc-reap-{run_id}"))
                        .spawn(move || inner.reap(run_id, tenant, sub))
                        .expect("spawn service reaper");
                }
                Err(e) => {
                    // submissions are pre-validated, so this is a raced
                    // engine-side refusal: release the reservation and
                    // journal the run as failed so it stays observable.
                    // A resubmission already has a stream (with the real
                    // workflow name) — only the failure is appended.
                    self.metrics.failed.inc(&tenant);
                    let mut st = self.state.lock().unwrap();
                    st.starting.remove(&run_id);
                    if let Some(n) = st.tenant_live.get_mut(&tenant) {
                        *n = n.saturating_sub(1);
                    }
                    drop(st);
                    let mut events = Vec::new();
                    if !resubmission {
                        events.push(JournalEvent::RunSubmitted { workflow: wf_name });
                    }
                    events.push(JournalEvent::RunFailed { message: e });
                    let _ = self.journal.append_batch(run_id, &events);
                    self.compact_candidates.lock().unwrap().insert(run_id);
                    self.cv.notify_all();
                }
            }
        }
    }

    /// Wait one run out, then fold its outcome into metrics and free its
    /// admission slot.
    fn reap(&self, run_id: u64, tenant: String, sub: Submitted) {
        let result = sub.wait();
        match result.run.phase() {
            RunPhase::Succeeded => self.metrics.succeeded.inc(&tenant),
            RunPhase::Cancelled => self.metrics.cancelled.inc(&tenant),
            _ => self.metrics.failed.inc(&tenant),
        }
        // fold the run's flight-recorder traffic into the tenant families
        self.metrics.log_bytes.add(&tenant, result.run.metrics.log_bytes.get());
        self.metrics.log_flushes.add(&tenant, result.run.metrics.log_flushes.get());
        let mut st = self.state.lock().unwrap();
        if let Some(lr) = st.live.remove(&run_id) {
            self.metrics.run_duration.observe(lr.started_at.elapsed());
        }
        st.recently_closed.insert(run_id, Instant::now());
        if let Some(n) = st.tenant_live.get_mut(&tenant) {
            *n = n.saturating_sub(1);
        }
        drop(st);
        self.compact_candidates.lock().unwrap().insert(run_id);
        self.cv.notify_all();
    }

    fn maintenance_loop(&self) {
        loop {
            {
                let st = self.state.lock().unwrap();
                if st.shutdown {
                    return;
                }
                let (st, _) =
                    self.tick_cv.wait_timeout(st, self.config.maintenance_interval).unwrap();
                if st.shutdown {
                    return;
                }
            }
            self.maintenance_tick();
        }
    }

    /// One maintenance pass: apply durable cancel markers, then compact
    /// closed runs that still carry raw segments.
    fn maintenance_tick(&self) {
        if let Some(h) = self.chaos.get() {
            h("service.tick");
        }
        // Cancel markers are only CLEARED once this service applied them
        // or proved them stale (the run is closed in the journal). A
        // marker for a run that is live in a *different* process sharing
        // the store is left alone — the owner's tick applies it;
        // clear-on-read here would silently lose that cancel.
        if let Ok(requests) = self.journal.pending_cancel_requests() {
            for (run_id, reason) in requests {
                match self.cancel_by_id(run_id, &reason) {
                    Ok(()) => {
                        self.metrics.cancel_requests.inc();
                        let _ = self.journal.clear_cancel_request(run_id);
                    }
                    Err(_) => {
                        // a run this service is still handling (starting/
                        // queued/live) is NEVER stale, whatever the
                        // journal's (pre-resubmission) phase says: a
                        // retried run in mid-dispatch replays as closed
                        // until RunResubmitted lands — clearing its
                        // marker here would silently drop the cancel.
                        // Leave it; the next tick applies it.
                        let ours = {
                            let st = self.state.lock().unwrap();
                            st.starting.contains(&run_id)
                                || st.live.contains_key(&run_id)
                                || st.queue.iter().any(|p| p.run_id == run_id)
                        };
                        if ours {
                            continue;
                        }
                        // stale iff the journal says the run closed
                        let closed = matches!(
                            self.journal.replay(run_id),
                            Ok(rec) if !matches!(rec.phase, RunPhase::Running)
                        );
                        if closed {
                            self.metrics.cancel_requests.inc();
                            let _ = self.journal.clear_cancel_request(run_id);
                        }
                    }
                }
            }
        }
        // prune the post-close grace map unconditionally (reap inserts
        // unconditionally — gating this on auto_compact would leak one
        // entry per closed run forever on services with compaction off)
        let grace = self.config.compaction_grace;
        {
            let mut st = self.state.lock().unwrap();
            let now = Instant::now();
            st.recently_closed.retain(|_, at| now.duration_since(*at) < grace);
        }
        if self.config.auto_compact {
            // One-time scan: history from before this service started
            // seeds the candidate set; afterwards candidates come only
            // from run closes, so steady-state ticks never re-list the
            // whole journal prefix.
            if !self.scanned.swap(true, Ordering::SeqCst) {
                if let Ok(ids) = self.journal.run_ids() {
                    self.compact_candidates.lock().unwrap().extend(ids);
                }
            }
            let candidates: Vec<u64> =
                self.compact_candidates.lock().unwrap().iter().copied().collect();
            for id in candidates {
                // cheap pre-checks WITHOUT the gate, so submissions are
                // not stalled behind journal reads
                if !matches!(self.journal.has_raw_segments(id), Ok(true)) {
                    // already just a snapshot: verified, stop considering
                    self.compact_candidates.lock().unwrap().remove(&id);
                    continue;
                }
                let terminal = matches!(
                    self.journal.replay(id),
                    Ok(rec) if !matches!(rec.phase, RunPhase::Running)
                );
                if !terminal {
                    // open (live in another process) or unreadable: not
                    // ours to compact — drop it from the loop (a
                    // close/retry on this service re-adds it)
                    self.compact_candidates.lock().unwrap().remove(&id);
                    continue;
                }
                // Serialize against retry enqueues (gate) and re-check
                // busy-ness under it immediately before compacting: a
                // retry admitted after the candidate list was built must
                // not have its fresh appends deleted out from under it.
                // The gate is held only across this one compact; the
                // grace period additionally keeps post-terminal
                // stragglers (late trace mirrors through a cached
                // segment writer) out of the window.
                let _gate = self.compact_gate.lock().unwrap();
                let busy = {
                    let st = self.state.lock().unwrap();
                    st.live.contains_key(&id)
                        || st.starting.contains(&id)
                        || st.queue.iter().any(|p| p.run_id == id)
                        || st.recently_closed.contains_key(&id)
                };
                if busy {
                    continue; // stays a candidate for a later tick
                }
                if self.journal.compact(id).is_ok() {
                    self.metrics.compactions.inc();
                    self.compact_candidates.lock().unwrap().remove(&id);
                }
            }
        }
    }

    /// Cancel a queued or live run (the shared core of
    /// [`WorkflowService::cancel`] and marker-driven cancels).
    fn cancel_by_id(&self, run_id: u64, reason: &str) -> Result<(), String> {
        let mut st = self.state.lock().unwrap();
        if let Some(pos) = st.queue.iter().position(|p| p.run_id == run_id) {
            let p = st.queue.remove(pos).expect("indexed queue entry vanished");
            self.metrics.cancelled.inc(&p.tenant);
            drop(st);
            // the queued run never journaled anything: give it a durable
            // record so the registry can answer for it (a resubmission
            // already has a stream — only the cancel is appended)
            let mut events = Vec::new();
            if !p.resubmission {
                events.push(JournalEvent::RunSubmitted { workflow: p.wf.name.clone() });
            }
            events.push(JournalEvent::RunCancelled { reason: reason.to_string() });
            self.journal.append_batch(run_id, &events)?;
            // the stream just closed Cancelled: compaction candidate
            self.compact_candidates.lock().unwrap().insert(run_id);
            self.cv.notify_all();
            return Ok(());
        }
        if st.starting.contains(&run_id) {
            // mid-dispatch: the run will be in `live` momentarily — the
            // caller (or the next maintenance tick, for markers) retries
            return Err(format!("run {run_id} is starting; retry the cancel shortly"));
        }
        if let Some(lr) = st.live.get(&run_id) {
            let run = Arc::clone(&lr.run);
            drop(st);
            if run.cancel(reason) {
                Ok(())
            } else {
                Err(format!("run {run_id} is already stopping"))
            }
        } else {
            drop(st);
            match self.journal.replay(run_id) {
                Ok(rec) => Err(format!(
                    "run {run_id} is not live on this service (phase {:?})",
                    rec.phase
                )),
                Err(e) => Err(format!("run {run_id} is unknown: {e}")),
            }
        }
    }
}

/// Queue/live snapshot (see [`WorkflowService::status`]).
#[derive(Debug, Clone)]
pub struct ServiceStatus {
    pub queued: usize,
    pub live: usize,
    pub queue_peak: usize,
    /// tenant → live runs right now.
    pub tenants_live: BTreeMap<String, usize>,
}

/// The multi-run workflow daemon. Owns one [`Engine`] (with its journal)
/// and serves many concurrent tenants; see the module docs. Dropping the
/// service stops admitting and joins its control threads — live runs
/// finish on their engine threads and are reaped in the background.
pub struct WorkflowService {
    inner: Arc<SvcInner>,
    registry: RunRegistry,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    maintenance: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl WorkflowService {
    /// Start the daemon over `engine` (which must have a journal attached
    /// — the service's durable registry and cancel/retry substrate).
    pub fn start(engine: Arc<Engine>, config: ServiceConfig) -> Result<WorkflowService, String> {
        let journal = engine
            .journal()
            .cloned()
            .ok_or_else(|| {
                "WorkflowService requires an engine with a journal attached \
                 (EngineBuilder::journal or ::journal_appender)"
                    .to_string()
            })?;
        let inner = Arc::new(SvcInner {
            engine,
            journal: Arc::clone(&journal),
            config,
            metrics: ServiceMetrics::default(),
            state: Mutex::new(SvcState {
                queue: VecDeque::new(),
                starting: BTreeSet::new(),
                live: BTreeMap::new(),
                recently_closed: BTreeMap::new(),
                tenant_live: BTreeMap::new(),
                tenant_started: BTreeMap::new(),
                start_log: Vec::new(),
                queue_peak: 0,
                draining: false,
                shutdown: false,
            }),
            cv: Condvar::new(),
            tick_cv: Condvar::new(),
            compact_candidates: Mutex::new(BTreeSet::new()),
            scanned: AtomicBool::new(false),
            compact_gate: Mutex::new(()),
            chaos: OnceLock::new(),
        });
        let d = Arc::clone(&inner);
        let dispatcher = std::thread::Builder::new()
            .name("dflow-svc-dispatch".to_string())
            .spawn(move || d.dispatch_loop())
            .map_err(|e| e.to_string())?;
        let m = Arc::clone(&inner);
        let maintenance = std::thread::Builder::new()
            .name("dflow-svc-maint".to_string())
            .spawn(move || m.maintenance_loop())
            .map_err(|e| e.to_string())?;
        Ok(WorkflowService {
            inner,
            registry: RunRegistry::new(journal),
            dispatcher: Mutex::new(Some(dispatcher)),
            maintenance: Mutex::new(Some(maintenance)),
        })
    }

    /// Install a fault-injection hook ([`crate::check::chaos`]) on the
    /// service's maintenance tick AND every engine-owned event boundary
    /// (placements, pod binds, scheduler dispatch). First caller wins.
    pub fn set_chaos(&self, hook: crate::util::ChaosHook) {
        let _ = self.inner.chaos.set(hook.clone());
        self.inner.engine.set_chaos_hook(hook);
    }

    /// Submit a workflow on behalf of `tenant`. Returns the run id
    /// immediately — the run is **queued**; the dispatcher starts it under
    /// admission control. Rejects (with a clear error) when the bounded
    /// queue is full or the service is draining.
    pub fn submit(&self, tenant: &str, wf: Workflow) -> Result<u64, String> {
        self.enqueue(tenant, wf, Vec::new(), None, false)
    }

    /// [`WorkflowService::submit`] with reused steps spliced in (§2.5).
    pub fn submit_with_reuse(
        &self,
        tenant: &str,
        wf: Workflow,
        reuse: Vec<ReusedStep>,
    ) -> Result<u64, String> {
        self.enqueue(tenant, wf, reuse, None, false)
    }

    fn enqueue(
        &self,
        tenant: &str,
        wf: Workflow,
        reuse: Vec<ReusedStep>,
        run_id: Option<u64>,
        resubmission: bool,
    ) -> Result<u64, String> {
        // full static analysis against the engine's deployment plus the
        // service's own admission limits: error-severity findings reject
        // here, before the workflow can ever occupy a queue slot; the
        // engine journals surviving warnings as `RunLinted` at start
        let mut ctx = self.inner.engine.analysis_context();
        ctx.service = Some(crate::analysis::ServiceHints {
            max_live_runs: self.inner.config.max_live_runs,
        });
        let report = crate::analysis::Report::new(crate::analysis::analyze_with(&wf, &ctx));
        if report.has_errors() {
            self.inner.metrics.rejected.inc(tenant);
            return Err(report.error_summary(&wf.name));
        }
        // gate → state lock order, shared with the compaction loop: a
        // retry cannot slip into the queue between compaction's busy
        // re-check and the compact itself
        let _gate = self.inner.compact_gate.lock().unwrap();
        let mut st = self.inner.state.lock().unwrap();
        if st.draining {
            self.inner.metrics.rejected.inc(tenant);
            return Err("service is draining; submissions are disabled".to_string());
        }
        if st.queue.len() >= self.inner.config.queue_cap {
            self.inner.metrics.rejected.inc(tenant);
            return Err(format!(
                "admission queue is full ({} pending, cap {}); retry later",
                st.queue.len(),
                self.inner.config.queue_cap
            ));
        }
        let run_id = run_id.unwrap_or_else(crate::util::next_id);
        st.queue.push_back(Pending {
            run_id,
            tenant: tenant.to_string(),
            wf,
            reuse,
            resubmission,
            priority: self.inner.config.priority_for(tenant),
            queued_at: Instant::now(),
        });
        st.queue_peak = st.queue_peak.max(st.queue.len());
        self.inner.metrics.submitted.inc(tenant);
        drop(st);
        self.inner.cv.notify_all();
        Ok(run_id)
    }

    /// Cancel a queued or live run. Queued runs are dropped from the
    /// queue (and journaled `RunCancelled` so the registry can answer for
    /// them); live runs cancel through their attempt tokens and close as
    /// [`RunPhase::Cancelled`] once in-flight OPs stop. Use
    /// [`WorkflowService::run`] + `wait_finished` to block on the stop.
    pub fn cancel(&self, run_id: u64, reason: &str) -> Result<(), String> {
        self.inner.cancel_by_id(run_id, reason)
    }

    /// Re-queue a **closed** journaled run under the same run id, with
    /// every journaled success spliced in — exactly the non-succeeded
    /// suffix executes again (`Engine::resubmit`, service-managed). `wf`
    /// must be the same workflow definition the run was submitted with.
    pub fn retry(&self, tenant: &str, wf: Workflow, run_id: u64) -> Result<u64, String> {
        {
            let st = self.inner.state.lock().unwrap();
            if st.live.contains_key(&run_id) || st.starting.contains(&run_id) {
                return Err(format!("run {run_id} is still live; cancel it first"));
            }
            if st.queue.iter().any(|p| p.run_id == run_id) {
                return Err(format!("run {run_id} is already queued"));
            }
        }
        let rec = self.inner.journal.replay(run_id)?;
        if rec.workflow != wf.name {
            return Err(format!(
                "journaled run {run_id} belongs to workflow '{}', not '{}'",
                rec.workflow, wf.name
            ));
        }
        // NOTE: a journal phase of `Running` is allowed through — that is
        // the crash-recovery case (`Engine::resubmit`'s raison d'être): the
        // process driving the run died without closing the stream. The
        // live/queued checks above already refuse runs THIS service still
        // owns; a run live in a *different* process sharing the store is
        // indistinguishable from a crash and remains the operator's call.
        self.enqueue(tenant, wf, rec.reusable_steps(), Some(run_id), true)
    }

    /// Tail a run's journal as a stream of [`Recorded`] events.
    pub fn watch(&self, run_id: u64) -> RunWatch {
        RunWatch::new(Arc::clone(&self.inner.journal), run_id)
    }

    /// Live handle of an executing run (`None` when queued or closed).
    pub fn run(&self, run_id: u64) -> Option<Arc<WorkflowRun>> {
        self.inner.state.lock().unwrap().live.get(&run_id).map(|lr| Arc::clone(&lr.run))
    }

    /// Queue/live snapshot (`live` includes runs mid-dispatch).
    pub fn status(&self) -> ServiceStatus {
        let st = self.inner.state.lock().unwrap();
        ServiceStatus {
            queued: st.queue.len(),
            live: st.live.len() + st.starting.len(),
            queue_peak: st.queue_peak,
            tenants_live: st.tenant_live.clone(),
        }
    }

    /// Control-plane status document (what `dflow service-status` would
    /// print): queue depth, live runs per tenant, per-tenant counters.
    pub fn status_json(&self) -> Json {
        let st = self.inner.state.lock().unwrap();
        let queued: Vec<Json> = st
            .queue
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("run_id", Json::n(p.run_id as f64)),
                    ("tenant", Json::s(p.tenant.clone())),
                    ("workflow", Json::s(p.wf.name.clone())),
                    ("resubmission", Json::Bool(p.resubmission)),
                ])
            })
            .collect();
        let live: Vec<Json> = st
            .live
            .iter()
            .map(|(id, lr)| {
                Json::obj(vec![
                    ("run_id", Json::n(*id as f64)),
                    ("tenant", Json::s(lr.tenant.clone())),
                    ("workflow", Json::s(lr.run.workflow_name.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("queued", Json::Arr(queued)),
            ("starting", Json::n(st.starting.len() as f64)),
            ("live", Json::Arr(live)),
            ("queue_peak", Json::n(st.queue_peak as f64)),
            ("metrics", self.inner.metrics.to_json()),
        ])
    }

    /// Full metrics export: the engine's families (fleet-merged run
    /// registries, scheduler, timer wheel, placement) plus the service's
    /// control-plane families. Render with
    /// [`crate::obs::MetricsDoc::to_prometheus`] for a scrape endpoint or
    /// `to_json` for dashboards — this is the document behind
    /// `dflow metrics`.
    pub fn export_metrics(&self) -> MetricsDoc {
        let mut doc = self.inner.engine.export_metrics();
        let m = &self.inner.metrics;
        let tenant_families: [(&str, &str, &LabelCounters); 8] = [
            (
                "dflow_svc_submitted_total",
                "Submissions accepted into the admission queue.",
                &m.submitted,
            ),
            (
                "dflow_svc_rejected_total",
                "Submissions rejected at admission (queue full, draining, lint errors).",
                &m.rejected,
            ),
            ("dflow_svc_started_total", "Runs started by the dispatcher.", &m.started),
            ("dflow_svc_succeeded_total", "Runs reaped as succeeded.", &m.succeeded),
            ("dflow_svc_failed_total", "Runs reaped as failed.", &m.failed),
            ("dflow_svc_cancelled_total", "Runs reaped as cancelled.", &m.cancelled),
            (
                "dflow_svc_log_bytes_total",
                "Flight-recorder bytes flushed by the tenant's reaped runs.",
                &m.log_bytes,
            ),
            (
                "dflow_svc_log_flushes_total",
                "Attempt log-buffer flushes by the tenant's reaped runs.",
                &m.log_flushes,
            ),
        ];
        for (name, help, counters) in tenant_families {
            for (tenant, v) in counters.snapshot() {
                doc.counter_labeled(name, help, &[("tenant", tenant.as_str())], v);
            }
        }
        for (tenant, v) in m.live_peak.snapshot() {
            doc.gauge_labeled(
                "dflow_svc_live_peak_runs",
                "High-water mark of concurrently live runs per tenant.",
                &[("tenant", tenant.as_str())],
                v as f64,
            );
        }
        doc.counter(
            "dflow_svc_compactions_total",
            "Closed-run journal compactions performed by the maintenance tick.",
            m.compactions.get(),
        );
        doc.counter(
            "dflow_svc_cancel_requests_total",
            "Durable cancel markers applied by the maintenance tick.",
            m.cancel_requests.get(),
        );
        doc.summary(
            "dflow_svc_queue_wait_seconds",
            "Admission-queue wait: submission accepted to dispatcher start.",
            &[],
            &m.queue_wait.summary(),
        );
        doc.summary(
            "dflow_svc_run_seconds",
            "Run wall-clock: dispatcher start to reaped close.",
            &[],
            &m.run_duration.summary(),
        );
        let st = self.inner.state.lock().unwrap();
        doc.gauge(
            "dflow_svc_queue_depth",
            "Queued submissions awaiting dispatch.",
            st.queue.len() as f64,
        );
        doc.gauge(
            "dflow_svc_live_runs",
            "Currently executing runs (mid-dispatch included).",
            (st.live.len() + st.starting.len()) as f64,
        );
        doc.gauge(
            "dflow_svc_queue_peak",
            "High-water mark of the admission queue.",
            st.queue_peak as f64,
        );
        // current backend-slot usage, summed over the tenant's live runs
        // (quota groundwork: today's quota counts runs, not slots — these
        // gauges measure slot pressure before it gets enforced)
        let mut slots: BTreeMap<(String, String), u64> = BTreeMap::new();
        for lr in st.live.values() {
            for (backend, n) in lr.run.backend_slots() {
                *slots.entry((lr.tenant.clone(), backend)).or_insert(0) += n;
            }
        }
        for ((tenant, backend), n) in slots {
            doc.gauge_labeled(
                "dflow_svc_backend_slots",
                "Backend slots currently held by the tenant's live runs.",
                &[("tenant", tenant.as_str()), ("backend", backend.as_str())],
                n as f64,
            );
        }
        doc
    }

    /// Live fleet view (the `dflow top` surface): every executing run with
    /// its node-phase breakdown and age, plus queue pressure and the
    /// control-plane latency summaries.
    pub fn top_json(&self) -> Json {
        let st = self.inner.state.lock().unwrap();
        let live: Vec<Json> = st
            .live
            .iter()
            .map(|(id, lr)| {
                let nodes = Json::obj(vec![
                    ("pending", Json::n(lr.run.count_phase(NodePhase::Pending) as f64)),
                    ("running", Json::n(lr.run.count_phase(NodePhase::Running) as f64)),
                    ("succeeded", Json::n(lr.run.count_phase(NodePhase::Succeeded) as f64)),
                    ("failed", Json::n(lr.run.count_phase(NodePhase::Failed) as f64)),
                    ("skipped", Json::n(lr.run.count_phase(NodePhase::Skipped) as f64)),
                    ("reused", Json::n(lr.run.count_phase(NodePhase::Reused) as f64)),
                ]);
                Json::obj(vec![
                    ("run_id", Json::n(*id as f64)),
                    ("tenant", Json::s(lr.tenant.clone())),
                    ("workflow", Json::s(lr.run.workflow_name.clone())),
                    ("elapsed_ms", Json::n(lr.started_at.elapsed().as_millis() as f64)),
                    ("nodes", nodes),
                ])
            })
            .collect();
        Json::obj(vec![
            ("live", Json::Arr(live)),
            ("starting", Json::n(st.starting.len() as f64)),
            ("queued", Json::n(st.queue.len() as f64)),
            ("queue_peak", Json::n(st.queue_peak as f64)),
            ("queue_wait", self.inner.metrics.queue_wait.summary().to_json()),
            ("run_duration", self.inner.metrics.run_duration.summary().to_json()),
        ])
    }

    /// `(tenant, run_id)` pairs in dispatch order — the fair-share
    /// evidence trail.
    pub fn start_order(&self) -> Vec<(String, u64)> {
        self.inner.state.lock().unwrap().start_log.clone()
    }

    /// Per-tenant counters.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.inner.metrics
    }

    /// The durable run registry (list/get/timeline over the journal).
    pub fn registry(&self) -> &RunRegistry {
        &self.registry
    }

    /// The journal behind this service.
    pub fn journal(&self) -> &Arc<Journal> {
        &self.inner.journal
    }

    /// The engine this service drives.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.inner.engine
    }

    /// Run one maintenance pass now (tests; the background tick does this
    /// on [`ServiceConfig::maintenance_interval`]).
    pub fn maintenance_tick(&self) {
        self.inner.maintenance_tick();
    }

    /// Stop admitting new submissions (queued ones still start).
    pub fn drain_admissions(&self) {
        self.inner.state.lock().unwrap().draining = true;
    }

    /// Block until no runs are queued or live, or `timeout` elapses;
    /// returns whether the service is idle.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        while !(st.queue.is_empty() && st.starting.is_empty() && st.live.is_empty()) {
            if Instant::now() >= deadline {
                return false;
            }
            let (g, _) = self.inner.cv.wait_timeout(st, Duration::from_millis(20)).unwrap();
            st = g;
        }
        true
    }
}

impl Drop for WorkflowService {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.draining = true;
            st.shutdown = true;
        }
        self.inner.cv.notify_all();
        self.inner.tick_cv.notify_all();
        for handle in [
            self.dispatcher.lock().unwrap().take(),
            self.maintenance.lock().unwrap().take(),
        ]
        .into_iter()
        .flatten()
        {
            let _ = handle.join();
        }
        // live runs finish on engine threads; their reapers hold SvcInner
        // and complete the bookkeeping on their own
    }
}

/// Where a [`RunWatch`] is in its run's stream.
enum WatchCursor {
    /// Incremental raw-segment tail (the normal live path): next segment
    /// to read + records of it already delivered. Each poll costs one
    /// listing plus the open segment — O(open segment), not O(history).
    Tail { seg: u64, rec: usize },
    /// Full-replay fallback (the stream holds a compaction snapshot a
    /// raw tail cannot express): `delivered` records already handed out.
    Full { delivered: usize },
}

/// Journal tailer: the durable half of `dflow watch`. Polls the run's
/// record stream and yields only the suffix it has not delivered yet —
/// works live (the run is appending), post-hoc (full history replays), and
/// cross-process (any process sharing the store can watch).
pub struct RunWatch {
    journal: Arc<Journal>,
    run_id: u64,
    cursor: WatchCursor,
}

impl RunWatch {
    /// Tail `run_id` on `journal` from the beginning of its stream.
    pub fn new(journal: Arc<Journal>, run_id: u64) -> RunWatch {
        RunWatch { journal, run_id, cursor: WatchCursor::Tail { seg: 0, rec: 0 } }
    }

    /// Events appended since the last poll (empty when none yet — a
    /// queued run has no stream until it starts).
    pub fn poll(&mut self) -> Result<Vec<Recorded>, String> {
        if let WatchCursor::Tail { seg, rec } = &mut self.cursor {
            match self.journal.tail_raw(self.run_id, seg, rec) {
                Ok(Some(fresh)) => return Ok(fresh),
                Ok(None) => {
                    // compacted stream: fall back to full replay. A watch
                    // that already delivered raw records and then sees a
                    // compaction (possible only when no live service owns
                    // the run) re-delivers the folded history as one
                    // snapshot-seeded stream.
                    self.cursor = WatchCursor::Full { delivered: 0 };
                }
                Err(e) => {
                    // a compaction can land between tail_raw's listing and
                    // its segment download: the raw segment this watch was
                    // tailing vanishes mid-poll. A snapshot now existing
                    // is proof that is what happened — resume from the
                    // snapshot-seeded stream instead of surfacing a
                    // vanished-segment error to a live watcher. Any other
                    // failure (including being unable to list) propagates.
                    if self.journal.has_snapshot(self.run_id).unwrap_or(false) {
                        self.cursor = WatchCursor::Full { delivered: 0 };
                    } else {
                        return Err(e);
                    }
                }
            }
        }
        let events = match self.journal.events(self.run_id) {
            Ok((events, _torn)) => events,
            Err(e) if e.contains("no journal records") => return Ok(Vec::new()),
            Err(e) => return Err(e),
        };
        let WatchCursor::Full { delivered } = &mut self.cursor else {
            unreachable!("tail mode returns above")
        };
        let fresh: Vec<Recorded> = events.into_iter().skip(*delivered).collect();
        *delivered += fresh.len();
        Ok(fresh)
    }

    /// Follow the stream, invoking `f` per event, until the run reaches a
    /// terminal phase; returns that phase. Poll cadence is `interval`.
    ///
    /// Waits indefinitely for the stream to *appear* (a queued run has no
    /// records until it starts) — callers watching an id of unknown
    /// provenance should verify it exists (`Journal::run_ids`) first.
    pub fn follow(
        &mut self,
        interval: Duration,
        mut f: impl FnMut(&Recorded),
    ) -> Result<RunPhase, String> {
        let mut terminal: Option<RunPhase> = None;
        loop {
            for rec in self.poll()? {
                f(&rec);
                match &rec.event {
                    JournalEvent::RunSucceeded => terminal = Some(RunPhase::Succeeded),
                    JournalEvent::RunFailed { .. } => terminal = Some(RunPhase::Failed),
                    JournalEvent::RunCancelled { .. } => terminal = Some(RunPhase::Cancelled),
                    // a resubmission re-opens the stream
                    JournalEvent::RunSubmitted { .. }
                    | JournalEvent::RunResubmitted { .. } => terminal = None,
                    JournalEvent::Snapshot { run } => {
                        terminal = if matches!(run.phase, RunPhase::Running) {
                            None
                        } else {
                            Some(run.phase)
                        };
                    }
                    _ => {}
                }
            }
            if let Some(p) = terminal {
                return Ok(p);
            }
            std::thread::sleep(interval);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ContainerTemplate, FnOp, ParamType, Signature, Step, Steps};
    use crate::engine::Backend;
    use crate::storage::MemStorage;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn quick_wf(name: &str) -> Workflow {
        let op = Arc::new(FnOp::new(
            Signature::new().out_param("v", ParamType::Int),
            |ctx| {
                ctx.set("v", 1i64);
                Ok(())
            },
        ));
        Workflow::new(name)
            .container(ContainerTemplate::new("op", op))
            .steps(Steps::new("main").then(Step::new("s", "op")))
            .entrypoint("main")
    }

    fn service() -> WorkflowService {
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder()
                .backend(Backend::local_slots("box", 8))
                .journal(journal)
                .build(),
        );
        WorkflowService::start(engine, ServiceConfig::default()).unwrap()
    }

    #[test]
    fn submit_runs_and_registry_records() {
        let svc = service();
        let id = svc.submit("alice", quick_wf("w")).unwrap();
        assert!(svc.wait_idle(Duration::from_secs(20)));
        let rec = svc.registry().get_run(id).unwrap();
        assert_eq!(rec.phase, RunPhase::Succeeded);
        assert_eq!(svc.metrics().succeeded.get("alice"), 1);
        assert_eq!(svc.metrics().submitted.get("alice"), 1);
    }

    #[test]
    fn queue_cap_rejects_with_clear_error() {
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder().backend(Backend::local_slots("box", 1)).journal(journal).build(),
        );
        // max_live 1 and a 1-slot queue: the second queued submission
        // must be rejected, not buffered
        let cfg = ServiceConfig {
            max_live_runs: 1,
            queue_cap: 1,
            ..ServiceConfig::default()
        };
        let svc = WorkflowService::start(engine, cfg).unwrap();
        let slow = Arc::new(FnOp::new(Signature::new(), |_| {
            std::thread::sleep(Duration::from_millis(150));
            Ok(())
        }));
        let slow_wf = |name: &str| {
            Workflow::new(name)
                .container(ContainerTemplate::new("op", Arc::clone(&slow)))
                .steps(Steps::new("main").then(Step::new("s", "op")))
                .entrypoint("main")
        };
        svc.submit("a", slow_wf("w1")).unwrap();
        // give the dispatcher a moment to start w1, then fill the queue
        std::thread::sleep(Duration::from_millis(50));
        svc.submit("a", slow_wf("w2")).unwrap();
        let err = svc.submit("a", slow_wf("w3")).unwrap_err();
        assert!(err.contains("queue is full"), "{err}");
        assert_eq!(svc.metrics().rejected.get("a"), 1);
        assert!(svc.wait_idle(Duration::from_secs(20)));
    }

    #[test]
    fn cancel_of_a_queued_run_journals_cancelled() {
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder().backend(Backend::local_slots("box", 1)).journal(journal).build(),
        );
        let cfg = ServiceConfig { max_live_runs: 1, ..ServiceConfig::default() };
        let svc = WorkflowService::start(engine, cfg).unwrap();
        let slow = Arc::new(FnOp::new(Signature::new(), |_| {
            std::thread::sleep(Duration::from_millis(200));
            Ok(())
        }));
        let wf = Workflow::new("blocker")
            .container(ContainerTemplate::new("op", slow))
            .steps(Steps::new("main").then(Step::new("s", "op")))
            .entrypoint("main");
        svc.submit("a", wf).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let queued = svc.submit("a", quick_wf("victim")).unwrap();
        svc.cancel(queued, "changed my mind").unwrap();
        let rec = svc.journal().replay(queued).unwrap();
        assert_eq!(rec.phase, RunPhase::Cancelled);
        assert_eq!(rec.message, "changed my mind");
        assert_eq!(svc.metrics().cancelled.get("a"), 1);
        assert!(svc.wait_idle(Duration::from_secs(20)));
        // the cancelled run never started
        assert!(!svc.start_order().iter().any(|(_, id)| *id == queued));
    }

    #[test]
    fn retry_of_open_or_unknown_run_is_refused() {
        let svc = service();
        let err = svc.retry("a", quick_wf("w"), 424242).unwrap_err();
        assert!(err.contains("424242"), "{err}");
        // a live run refuses retry
        let slow = Arc::new(FnOp::new(Signature::new(), |_| {
            std::thread::sleep(Duration::from_millis(200));
            Ok(())
        }));
        let wf = Workflow::new("w")
            .container(ContainerTemplate::new("op", slow))
            .steps(Steps::new("main").then(Step::new("s", "op")))
            .entrypoint("main");
        let id = svc.submit("a", wf.clone()).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let err = svc.retry("a", wf, id).unwrap_err();
        assert!(err.contains("live") || err.contains("queued"), "{err}");
        assert!(svc.wait_idle(Duration::from_secs(20)));
    }

    #[test]
    fn watch_streams_incrementally_and_follow_sees_terminal() {
        let svc = service();
        let id = svc.submit("alice", quick_wf("w")).unwrap();
        let mut watch = svc.watch(id);
        let phase = watch
            .follow(Duration::from_millis(10), |_| {})
            .unwrap();
        assert_eq!(phase, RunPhase::Succeeded);
        // a fresh watch replays the full history, incrementally
        let mut watch2 = svc.watch(id);
        let first = watch2.poll().unwrap();
        assert!(!first.is_empty());
        assert!(watch2.poll().unwrap().is_empty(), "nothing new after full delivery");
    }

    #[test]
    fn maintenance_tick_compacts_closed_runs_and_applies_cancel_markers() {
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder().backend(Backend::local_slots("box", 8)).journal(journal).build(),
        );
        // zero grace so a just-closed run compacts on the explicit tick;
        // park the background tick so it cannot race the explicit ones
        // (marker double-processing would double-count cancel_requests)
        let cfg = ServiceConfig {
            compaction_grace: Duration::ZERO,
            maintenance_interval: Duration::from_secs(3600),
            ..ServiceConfig::default()
        };
        let svc = WorkflowService::start(engine, cfg).unwrap();
        let id = svc.submit("alice", quick_wf("w")).unwrap();
        assert!(svc.wait_idle(Duration::from_secs(20)));
        assert!(svc.journal().has_raw_segments(id).unwrap());
        svc.maintenance_tick();
        assert!(!svc.journal().has_raw_segments(id).unwrap(), "tick must compact");
        assert!(svc.metrics().compactions.get() >= 1);
        // a marker for a closed run is cleared as stale (counted, no-op)
        svc.journal().request_cancel(id, "late").unwrap();
        svc.maintenance_tick();
        assert_eq!(svc.metrics().cancel_requests.get(), 1);
        assert!(svc.journal().pending_cancel_requests().unwrap().is_empty());
        // the closed run's phase is untouched
        assert_eq!(svc.registry().get_run(id).unwrap().phase, RunPhase::Succeeded);
        // the run left the candidate set when it compacted: a second tick
        // does not re-compact (or re-list) it
        svc.maintenance_tick();
        assert_eq!(svc.metrics().compactions.get(), 1, "no re-compaction");
    }

    #[test]
    fn cancel_marker_for_a_foreign_live_run_is_left_pending() {
        // a marker naming a run this service does not own (journal says
        // Running — e.g. live in another process) must NOT be consumed
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder().backend(Backend::local_slots("box", 8)).journal(journal).build(),
        );
        let cfg = ServiceConfig {
            maintenance_interval: Duration::from_secs(3600),
            ..ServiceConfig::default()
        };
        let svc = WorkflowService::start(engine, cfg).unwrap();
        let foreign = crate::util::next_id();
        svc.journal()
            .append(foreign, &JournalEvent::RunSubmitted { workflow: "elsewhere".into() })
            .unwrap();
        svc.journal().request_cancel(foreign, "from another shell").unwrap();
        svc.maintenance_tick();
        let pending = svc.journal().pending_cancel_requests().unwrap();
        assert_eq!(pending.len(), 1, "foreign-run marker must survive the tick");
        assert_eq!(pending[0].0, foreign);
        assert_eq!(svc.metrics().cancel_requests.get(), 0);
    }

    #[test]
    fn retry_reruns_only_the_failed_suffix() {
        let svc = service();
        let calls = Arc::new(AtomicU32::new(0));
        let c2 = Arc::clone(&calls);
        let good = Arc::new(FnOp::new(
            Signature::new().out_param("v", ParamType::Int),
            move |ctx| {
                c2.fetch_add(1, Ordering::SeqCst);
                ctx.set("v", 7i64);
                Ok(())
            },
        ));
        let fail_once = Arc::new(AtomicU32::new(0));
        let f2 = Arc::clone(&fail_once);
        let flaky = Arc::new(FnOp::new(Signature::new(), move |_| {
            if f2.fetch_add(1, Ordering::SeqCst) == 0 {
                Err(crate::core::OpError::Fatal("first time fails".into()))
            } else {
                Ok(())
            }
        }));
        let wf = Workflow::new("two-step")
            .container(ContainerTemplate::new("good", good))
            .container(ContainerTemplate::new("flaky", flaky))
            .steps(
                Steps::new("main")
                    .then(Step::new("a", "good").key("step-a"))
                    .then(Step::new("b", "flaky").key("step-b")),
            )
            .entrypoint("main");
        let id = svc.submit("alice", wf.clone()).unwrap();
        assert!(svc.wait_idle(Duration::from_secs(20)));
        assert_eq!(svc.registry().get_run(id).unwrap().phase, RunPhase::Failed);
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        // retry: same run id, step a reused, only b re-executes
        let rid = svc.retry("alice", wf, id).unwrap();
        assert_eq!(rid, id);
        assert!(svc.wait_idle(Duration::from_secs(20)));
        let rec = svc.registry().get_run(id).unwrap();
        assert_eq!(rec.phase, RunPhase::Succeeded);
        assert_eq!(rec.resubmissions, 1);
        assert_eq!(calls.load(Ordering::SeqCst), 1, "step a must be reused, not re-run");
    }
}
