//! # dflow-rs
//!
//! A from-scratch reproduction of **Dflow** (Liu et al., 2024): a
//! cloud-native-style workflow engine for AI-for-Science computing, built as
//! the L3 coordinator of a three-layer Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * [`analysis`] — workflow static analysis (`dflow lint`): a multi-pass
//!   collect-all diagnostics engine with stable `DFxxx` codes (structural,
//!   dataflow, placement feasibility, policy/capacity) that gates
//!   admission at `Engine::submit*` / `WorkflowService::submit` and powers
//!   the `dflow lint` CLI.
//! * [`core`] — the workflow language: OP templates, typed
//!   parameters/artifacts, `Step`, `Steps`/`Dag` super-OPs, recursion,
//!   conditions and `Slices` (map/reduce over parallel steps).
//! * [`engine`] — the scheduler: an Argo-equivalent state machine with
//!   retries, timeouts, `continue_on` fault-tolerance policies, and the
//!   key/reuse restart mechanism (§2.4–2.5 of the paper).
//! * [`cluster`] — a Kubernetes-like cluster simulator (typed nodes, pods,
//!   bin-packing, virtual HPC nodes à la wlm-operator).
//! * [`hpc`] — a Slurm-like partition/queue/job simulator reachable through
//!   the `DispatcherExecutor` plugin (§2.6).
//! * [`executor`] — the `Executor` plugin surface (§2.6).
//! * [`storage`] — the 5-method `StorageClient` artifact-store plugin
//!   surface (§2.8) with local, in-memory and latency-modelled backends,
//!   plus a content-addressed chunked dedup layer (`storage::cas`) that
//!   makes step-to-step artifact forwarding a zero-copy manifest ref-bump.
//! * [`journal`] — the durable run journal: every run-lifecycle transition
//!   is appended as a checksummed record through the storage plugin
//!   surface, so a fresh process can replay a crashed run and resubmit it
//!   with every journaled success reused (`Engine::resubmit`), and a
//!   `RunRegistry` serves `list_runs`/`get_run`/`node_timeline` queries.
//!   A bounded background `Appender` batches event appends (one segment
//!   upload per drained batch instead of one per event).
//! * [`service`] — the workflow service control plane: a multi-run daemon
//!   (`WorkflowService`) over one engine with a bounded admission queue,
//!   per-tenant quotas and fair-share dispatch, live run lifecycle
//!   (cancel/retry/watch), and service-owned maintenance (durable cancel
//!   markers, auto-compaction of closed runs) — the `dflow` CLI's server
//!   side.
//! * [`obs`] — end-to-end run telemetry: causal `run → node → attempt`
//!   phase spans, log-linear latency histograms (p50/p90/p99/max),
//!   Prometheus/JSON metric exporters, and derived run profiles with
//!   critical-path reconstruction (`dflow metrics` / `profile` / `top`).
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt` produced by
//!   the python compile path and executes them on the request path.
//! * [`science`] — the AOT compute payloads (MD, NN-potential training,
//!   EOS, docking) plus pure-rust reference implementations.
//! * [`apps`] — the paper's §3 applications (FPOP, APEX, Rid-kit, DeePKS,
//!   VSW, TESLA) as reusable workflow builders.
//!
//! Python runs only at build time (`make artifacts`); the engine and every
//! example/bench in this crate are a self-contained Rust binary afterwards.

pub mod analysis;
pub mod apps;
pub mod bench_util;
pub mod check;
pub mod cluster;
pub mod core;
pub mod engine;
pub mod executor;
pub mod hpc;
pub mod journal;
pub mod jsonx;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod science;
pub mod service;
pub mod storage;
pub mod util;

// Re-exports of the most-used API surface (populated as modules land).
