//! C7 — telemetry overhead: the end-to-end span/histogram layer must be
//! close to free on the coordinator hot path. The same 10k-node
//! diamond-chain DAG (no-op payload, worst case for relative overhead)
//! runs with telemetry on (the default: per-attempt causal spans, phase
//! accumulators, per-run latency histograms) and off
//! (`EngineBuilder::telemetry(false)`); the acceptance assert is that the
//! best-of-N on-time stays within 5% (plus a small absolute slack for
//! timer noise) of the best-of-N off-time.
//!
//! The same contract covers the attempt flight recorder: with
//! `log_capture` on (the default) every attempt gets a log sink, and the
//! silent case — OPs that never log — must also stay within the 5%
//! budget of `log_capture(false)` (silence is free: no flush, no I/O, no
//! journal record).
//!
//! `make bench-snapshot` checks the rendered rows into `BENCH_obs.json`;
//! `BENCH_SMOKE=1` shrinks the DAG and loosens the ratio (tiny runs are
//! noise-dominated) without writing a snapshot.
//!
//! No AOT artifacts needed — this isolates the L3 coordinator + obs layer.

use std::time::{Duration, Instant};

use dflow::bench_util::{diamond_chain_workflow, Bench};
use dflow::engine::Engine;

/// One full DAG run; returns wall-clock. Asserts the telemetry surface
/// actually materialized (or stayed absent) so the two timings compare
/// the configurations they claim to.
fn run_once(target: usize, pool: usize, telemetry: bool) -> Duration {
    let (wf, _probe, nodes) = diamond_chain_workflow(target, pool);
    let engine = Engine::builder().parallelism(pool).telemetry(telemetry).build();
    let t0 = Instant::now();
    let r = engine.run(&wf).unwrap();
    let dt = t0.elapsed();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.run.nodes().len(), nodes);
    match r.run.spans() {
        Some(rec) => {
            assert!(telemetry, "telemetry(false) must not install a span recorder");
            // every attempt closed a span (+ the run-level accumulator
            // bundle), minus anything past the drop cap (none at 10k)
            assert!(
                rec.snapshot().len() >= nodes,
                "expected >= {nodes} closed spans, got {}",
                rec.snapshot().len()
            );
            assert_eq!(rec.dropped(), 0, "span cap must not trip at this scale");
        }
        None => assert!(!telemetry, "default-on telemetry missing from the run"),
    }
    dt
}

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let mut b = Bench::new("c7: telemetry overhead — spans+histograms on vs off");

    let target = if smoke { 2_002 } else { 10_002 };
    let pool = 8usize;
    let iters = if smoke { 2 } else { 5 };

    // interleave the two configurations so machine drift (thermal, cache,
    // background load) hits both equally; compare best-of-N
    let (mut best_on, mut best_off) = (Duration::MAX, Duration::MAX);
    for _ in 0..iters {
        best_off = best_off.min(run_once(target, pool, false));
        best_on = best_on.min(run_once(target, pool, true));
    }
    let nodes = target; // diamond_chain_workflow lands exactly on 3k+1 sizes
    b.row("telemetry off (best of N)", &format!("{:>10.2} ms", best_off.as_secs_f64() * 1e3));
    b.row("telemetry on  (best of N)", &format!("{:>10.2} ms", best_on.as_secs_f64() * 1e3));
    b.metric(
        "  span+histogram cost/step",
        (best_on.as_secs_f64() - best_off.as_secs_f64()).max(0.0) * 1e9 / nodes as f64,
        "ns (on minus off)",
    );
    let ratio = best_on.as_secs_f64() / best_off.as_secs_f64().max(1e-9);
    b.metric("  overhead ratio", ratio, "x (acceptance: <= 1.05 + slack)");
    // acceptance: <=5% relative overhead, with a small absolute slack so
    // sub-resolution timer noise cannot fail a fast run; smoke runs are
    // tiny and noise-dominated, so the ratio check loosens there
    let (rel, slack) = if smoke {
        (1.25, Duration::from_millis(40))
    } else {
        (1.05, Duration::from_millis(10))
    };
    assert!(
        best_on.as_secs_f64() <= best_off.as_secs_f64() * rel + slack.as_secs_f64(),
        "telemetry overhead out of budget: on {:?} vs off {:?} (allowed {:.0}% + {:?})",
        best_on,
        best_off,
        (rel - 1.0) * 100.0,
        slack
    );

    // flight-recorder overhead: capture on (per-attempt sink armed, no OP
    // logs a line) vs off, same interleaved best-of-N discipline and the
    // same acceptance budget — the recorder must cost nothing when silent
    {
        let run_logs = |capture: bool| {
            let (wf, _probe, nodes) = diamond_chain_workflow(target, pool);
            let engine = Engine::builder()
                .parallelism(pool)
                .telemetry(false) // isolate the recorder from the span layer
                .log_capture(capture)
                .build();
            let t0 = Instant::now();
            let r = engine.run(&wf).unwrap();
            let dt = t0.elapsed();
            assert!(r.succeeded(), "{:?}", r.error);
            assert_eq!(r.run.nodes().len(), nodes);
            assert_eq!(
                r.run.metrics.log_flushes.get(),
                0,
                "silent OPs must never trigger a flush"
            );
            dt
        };
        let (mut cap_on, mut cap_off) = (Duration::MAX, Duration::MAX);
        for _ in 0..iters {
            cap_off = cap_off.min(run_logs(false));
            cap_on = cap_on.min(run_logs(true));
        }
        b.row(
            "log capture off (best of N)",
            &format!("{:>10.2} ms", cap_off.as_secs_f64() * 1e3),
        );
        b.row(
            "log capture on  (best of N)",
            &format!("{:>10.2} ms", cap_on.as_secs_f64() * 1e3),
        );
        b.metric(
            "  silent-recorder cost/step",
            (cap_on.as_secs_f64() - cap_off.as_secs_f64()).max(0.0) * 1e9 / nodes as f64,
            "ns (on minus off)",
        );
        let ratio = cap_on.as_secs_f64() / cap_off.as_secs_f64().max(1e-9);
        b.metric("  capture overhead ratio", ratio, "x (acceptance: <= 1.05 + slack)");
        assert!(
            cap_on.as_secs_f64() <= cap_off.as_secs_f64() * rel + slack.as_secs_f64(),
            "log-capture overhead out of budget: on {:?} vs off {:?} (allowed {:.0}% + {:?})",
            cap_on,
            cap_off,
            (rel - 1.0) * 100.0,
            slack
        );
    }

    // exporter cost: rendering the full engine document (counters +
    // summaries + per-backend families) must be scrape-friendly
    {
        let (wf, _probe, _nodes) = diamond_chain_workflow(if smoke { 302 } else { 1_002 }, pool);
        let engine = Engine::builder().parallelism(pool).build();
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        let mut len = 0usize;
        b.case_n("export_metrics + to_prometheus", if smoke { 20 } else { 200 }, || {
            let text = engine.export_metrics().to_prometheus();
            len = text.len();
            std::hint::black_box(text);
        });
        assert!(len > 0, "empty prometheus export");
        b.metric("  export size", len as f64, "bytes");
    }

    if !smoke {
        Bench::write_snapshot("BENCH_obs.json", &[&b]).unwrap();
    }
}
