//! F8 — Fig. 8 TESLA concurrent-learning loop: per-phase cost breakdown and
//! the convergence trace (model deviation shrinking as data accumulates).

use dflow::apps::tesla::{self, TeslaConfig};
use dflow::bench_util::{artifacts_available, skip, Bench};
use dflow::engine::{Engine, NodePhase};
use dflow::runtime::Runtime;

fn main() {
    if !artifacts_available() {
        skip("fig8: TESLA loop");
        return;
    }
    let rt = Runtime::global().unwrap();
    dflow::bench_util::warmup(&rt, &["lj_ef", "md_step", "nn_ef", "train_step"]);
    let engine = Engine::builder().runtime(rt).build();
    let mut b = Bench::new("fig8: TESLA train/explore/screen/label loop");

    let cfg = TeslaConfig {
        n_models: 4,
        n_walkers: 4,
        md_calls: 3,
        train_steps: 80,
        max_iters: 3,
        init_configs: 8,
        conv_devi: 0.01, // effectively never converges -> full budget
        ..Default::default()
    };
    let (r, total) = b.case("3-iteration loop (4 models, 4 walkers)", || {
        let r = engine.run(&tesla::workflow(&cfg, 7)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });

    // convergence trace
    let trace = tesla::convergence_trace(&r.run, &cfg);
    for it in &trace {
        b.row(
            &format!("  iter {}", it.iter),
            &format!(
                "loss {:>9.4}   max_devi {:>7.4}   selected {:>3}",
                it.mean_loss, it.max_devi, it.n_selected
            ),
        );
    }
    assert!(trace.len() >= 2);
    assert!(
        trace.last().unwrap().max_devi <= trace[0].max_devi,
        "deviation should shrink: {trace:?}"
    );

    // phase cost breakdown from node timings
    let mut phase_ms: std::collections::BTreeMap<&str, u64> = Default::default();
    for n in r.run.nodes() {
        if n.phase != NodePhase::Succeeded || n.ended_ms < n.started_ms {
            continue;
        }
        let dur = n.ended_ms - n.started_ms;
        for (tag, pat) in [
            ("train", "/train["),
            ("explore", "/explore["),
            ("screen", "/devi"),
            ("label", "/label"),
        ] {
            if n.path.contains(pat) {
                *phase_ms.entry(tag).or_default() += dur;
            }
        }
    }
    for (tag, ms) in &phase_ms {
        b.metric(&format!("  {tag} span (incl. queue wait)"), *ms as f64, "ms");
    }
    b.metric("loop wall time", total.as_secs_f64(), "s");
    b.metric(
        "scheduler overhead (dispatch mean)",
        r.run.metrics.dispatch.mean().as_secs_f64() * 1e6,
        "µs",
    );
}
