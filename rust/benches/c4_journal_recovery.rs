//! C4 — durable run journal: replay throughput (events/sec, with and
//! without a compaction snapshot) and resubmit-with-reuse wall-clock vs a
//! cold run, the §2.5 restart claim made durable.

use std::sync::Arc;

use dflow::bench_util::Bench;
use dflow::core::{
    ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::{Engine, StepOutputs};
use dflow::journal::{Journal, JournalEvent, RunRegistry};
use dflow::storage::{MemStorage, StorageClient};

fn keyed_fanout(width: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            ctx.set("o", ctx.get_int("i")? * 10);
            Ok(())
        },
    ));
    Workflow::new("exp")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").parallelism(8))
                        .key("step-{{item}}"),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main")
}

fn main() {
    let mut b = Bench::new("c4: journal — replay throughput and warm resubmit");

    // 1) replay throughput over a synthetic 3000-node run journal
    let storage: Arc<dyn StorageClient> = Arc::new(MemStorage::new());
    let j = Journal::open(storage.clone()).unwrap();
    let run_id = dflow::util::next_id();
    j.append(run_id, &JournalEvent::RunSubmitted { workflow: "synthetic".into() }).unwrap();
    let nodes = 3000usize;
    for i in 0..nodes {
        let path = format!("main/t{i}");
        j.append(
            run_id,
            &JournalEvent::NodeScheduled { path: path.clone(), template: "op".into() },
        )
        .unwrap();
        j.append(run_id, &JournalEvent::NodeStarted { path: path.clone(), attempt: 0 })
            .unwrap();
        let mut out = StepOutputs::default();
        out.params.insert("o".into(), Value::Int(i as i64));
        j.append(
            run_id,
            &JournalEvent::NodeSucceeded { path, key: Some(format!("t{i}")), outputs: out },
        )
        .unwrap();
    }
    j.append(run_id, &JournalEvent::RunSucceeded).unwrap();
    let total_events = (3 * nodes + 2) as f64;

    let (rec, t_replay) = b.case("replay a 9002-event journal", || j.replay(run_id).unwrap());
    assert_eq!(rec.keyed.len(), nodes);
    b.metric(
        "  replay throughput",
        total_events / t_replay.as_secs_f64().max(1e-9),
        "events/s",
    );
    let report = j.compact(run_id).unwrap();
    b.row(
        "  compaction",
        &format!(
            "{} events folded into one snapshot ({} segments removed)",
            report.events_folded, report.segments_removed
        ),
    );
    let (rec2, t_snap) =
        b.case("replay after compaction (snapshot fast path)", || j.replay(run_id).unwrap());
    assert_eq!(rec2.keyed.len(), nodes);
    b.metric(
        "  snapshot replay speedup",
        t_replay.as_secs_f64() / t_snap.as_secs_f64().max(1e-9),
        "x",
    );

    // 2) cold run vs resubmit-with-reuse (64 × 5 ms keyed steps): the
    // §2.5 restart path fed straight from the journal
    let storage: Arc<dyn StorageClient> = Arc::new(MemStorage::new());
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(Arc::clone(&journal)).build();
    let wf = keyed_fanout(64);
    let (r_cold, t_cold) = b.case("cold run (64 x 5ms steps, journaled)", || {
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    let rid = r_cold.run.id;
    let (r_warm, t_warm) = b.case("resubmit from journal (100% reuse)", || {
        let r = engine.resubmit(&wf, rid).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    assert_eq!(r_warm.run.metrics.steps_reused.get(), 64);
    b.metric(
        "  resubmit-with-reuse speedup",
        t_cold.as_secs_f64() / t_warm.as_secs_f64().max(1e-9),
        "x (vs cold)",
    );
    let rows = RunRegistry::new(journal).list_runs().unwrap();
    b.row("  registry", &format!("{} runs queryable after the fact", rows.len()));
}
