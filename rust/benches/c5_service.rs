//! C5 — workflow service control plane: submission throughput through
//! admission control, time-to-first-node under N concurrent runs, the
//! batched vs per-event journal append cost (the fan-out hot-spot fix),
//! and a 1000-run admission wave drained through bounded live-run slots.
//!
//! `make bench-snapshot` checks the rendered rows into
//! `BENCH_service.json` for regression diffing; `BENCH_SMOKE=1`
//! (`make bench-smoke`) shrinks every case to an assert-only pass and
//! writes no snapshot.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dflow::bench_util::Bench;
use dflow::core::{
    ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::{Backend, Engine};
use dflow::journal::{Appender, Journal, JournalEvent, RunRegistry};
use dflow::service::{ServiceConfig, WorkflowService};
use dflow::storage::{CountingStorage, MemStorage, StorageClient};

fn small_dag(name: &str, work_ms: u64) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().out_param("v", ParamType::Int),
        move |ctx| {
            if work_ms > 0 {
                std::thread::sleep(Duration::from_millis(work_ms));
            }
            ctx.set("v", 1i64);
            Ok(())
        },
    ));
    Workflow::new(name)
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(Step::new("a", "op"))
                .then(Step::new("b", "op"))
                .then(Step::new("c", "op")),
        )
        .entrypoint("main")
}

fn fanout(name: &str, width: i64) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            ctx.set("y", ctx.get_int("x")? * 2);
            Ok(())
        },
    ));
    Workflow::new(name)
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main").then(
                Step::new("fan", "op")
                    .param("x", Value::ints(0..width))
                    .slices(Slices::over("x").stack("y").parallelism(16)),
            ),
        )
        .entrypoint("main")
}

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let mut b = Bench::new("c5: service control plane — admission, latency, batched journal");

    // 1) submission throughput: how fast does admission accept work?
    {
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder().backend(Backend::local_slots("box", 16)).journal(journal).build(),
        );
        let config = ServiceConfig {
            max_live_runs: 16,
            default_tenant_quota: 16,
            queue_cap: 4096,
            ..ServiceConfig::default()
        };
        let svc = WorkflowService::start(engine, config).unwrap();
        let n = if smoke { 32usize } else { 256 };
        let t = b
            .case(&format!("admit {n} submissions (3-node runs, 4 tenants)"), || {
                for i in 0..n {
                    let tenant = ["t0", "t1", "t2", "t3"][i % 4];
                    svc.submit(tenant, small_dag(&format!("wf-{i}"), 0)).unwrap();
                }
            })
            .1;
        b.metric("submission throughput", n as f64 / t.as_secs_f64(), "submits/s");
        let drained = svc.wait_idle(Duration::from_secs(300));
        assert!(drained, "service never drained");
        let rows = RunRegistry::new(Arc::clone(svc.journal())).list_runs().unwrap();
        b.row("runs journaled", &format!("{}", rows.len()));
    }

    // 2) time-to-first-node under N concurrent runs: submit N at once,
    //    measure submit→first-NodeStarted latency per run via the journal
    {
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder().backend(Backend::local_slots("box", 8)).journal(journal).build(),
        );
        let n = if smoke { 4usize } else { 16 };
        let config = ServiceConfig {
            max_live_runs: n,
            default_tenant_quota: n,
            queue_cap: 1024,
            ..ServiceConfig::default()
        };
        let svc = WorkflowService::start(engine, config).unwrap();
        let t0 = Instant::now();
        let submitted_ms = dflow::util::epoch_ms();
        let ids: Vec<u64> = (0..n)
            .map(|i| svc.submit("bench", small_dag(&format!("lat-{i}"), 10)).unwrap())
            .collect();
        assert!(svc.wait_idle(Duration::from_secs(300)), "never drained");
        let wall = t0.elapsed();
        let mut first_starts = Vec::new();
        for id in &ids {
            let events = svc.journal().events(*id).unwrap().0;
            if let Some(rec) = events
                .iter()
                .find(|r| matches!(r.event, JournalEvent::NodeStarted { .. }))
            {
                first_starts.push(rec.at_ms.saturating_sub(submitted_ms));
            }
        }
        first_starts.sort_unstable();
        let mean = first_starts.iter().sum::<u64>() as f64 / first_starts.len().max(1) as f64;
        let worst = first_starts.last().copied().unwrap_or(0);
        b.row(
            &format!("{n} concurrent 3-node runs"),
            &format!("all finished in {:.0} ms", wall.as_secs_f64() * 1e3),
        );
        b.metric("time-to-first-node (mean)", mean, "ms");
        b.metric("time-to-first-node (worst)", worst as f64, "ms");
    }

    // 3) batched vs per-event journal append cost for a 100-event fan-out
    {
        let width = 40i64; // ~3 events per slice + run envelope ≈ 123 events

        let sync_counting = Arc::new(CountingStorage::new(Arc::new(MemStorage::new())));
        let sync_journal = Arc::new(
            Journal::open(Arc::clone(&sync_counting) as Arc<dyn StorageClient>).unwrap(),
        );
        let sync_engine = Engine::builder().journal(Arc::clone(&sync_journal)).build();
        let (r1, t_sync) = b.case("fan-out, per-event (sync) journal", || {
            sync_engine.run(&fanout("sync", width)).unwrap()
        });
        assert!(r1.succeeded());
        let sync_uploads = sync_counting.uploads.load(std::sync::atomic::Ordering::Relaxed);

        let batch_counting = Arc::new(CountingStorage::new(Arc::new(MemStorage::new())));
        let batch_journal = Arc::new(
            Journal::open(Arc::clone(&batch_counting) as Arc<dyn StorageClient>).unwrap(),
        );
        let appender =
            Appender::with_config(Arc::clone(&batch_journal), 4096, Duration::from_millis(2));
        let batch_engine =
            Engine::builder().journal_appender(Arc::clone(&appender)).build();
        let (r2, t_batch) = b.case("fan-out, batched background appender", || {
            let r = batch_engine.run(&fanout("batched", width)).unwrap();
            appender.flush();
            r
        });
        assert!(r2.succeeded());
        let batch_uploads = batch_counting.uploads.load(std::sync::atomic::Ordering::Relaxed);

        let events = sync_journal.replay(r1.run.id).unwrap().events;
        b.row("events journaled per run", &format!("{events}"));
        b.row(
            "segment uploads",
            &format!("sync {sync_uploads} vs batched {batch_uploads}"),
        );
        b.metric(
            "upload reduction",
            sync_uploads as f64 / batch_uploads.max(1) as f64,
            "x",
        );
        b.metric(
            "run wall-clock ratio (sync/batched)",
            t_sync.as_secs_f64() / t_batch.as_secs_f64().max(1e-9),
            "x",
        );
        b.row("appender batches", &format!("{}", appender.batches()));
        assert!(
            batch_uploads * 5 <= sync_uploads,
            "acceptance: batched appender must reduce uploads ≥5× \
             ({batch_uploads} vs {sync_uploads})"
        );
    }

    // 4) the 1000-run admission wave: a full queue of 3-node runs admitted
    //    in one burst, then drained through 64 live-run slots — the
    //    control plane's sustained-throughput number
    {
        let n = if smoke { 64usize } else { 1000 };
        let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
        let engine = Arc::new(
            Engine::builder().backend(Backend::local_slots("box", 32)).journal(journal).build(),
        );
        let config = ServiceConfig {
            max_live_runs: 64,
            default_tenant_quota: 64,
            queue_cap: 2048,
            ..ServiceConfig::default()
        };
        let svc = WorkflowService::start(engine, config).unwrap();
        let tenants = ["t0", "t1", "t2", "t3", "t4", "t5", "t6", "t7"];
        let t_admit = b
            .case(&format!("admit a {n}-run wave (3-node runs, 8 tenants)"), || {
                for i in 0..n {
                    svc.submit(tenants[i % 8], small_dag(&format!("wave-{i}"), 0)).unwrap();
                }
            })
            .1;
        b.metric("wave admission throughput", n as f64 / t_admit.as_secs_f64(), "submits/s");
        let t_drain = b
            .case(&format!("drain the {n}-run wave (64 live slots)"), || {
                assert!(svc.wait_idle(Duration::from_secs(600)), "service never drained");
            })
            .1;
        let total = t_admit + t_drain;
        b.metric("sustained run throughput", n as f64 / total.as_secs_f64(), "runs/s");
        let rows = RunRegistry::new(Arc::clone(svc.journal())).list_runs().unwrap();
        assert_eq!(rows.len(), n, "every admitted run must close and journal");
    }

    if !smoke {
        Bench::write_snapshot("BENCH_service.json", &[&b]).unwrap();
    }
}
