//! F7 + C5 — Fig. 7 VSW screening funnel.
//!
//! Rows reproduce the paper's claims: shard-sliced docking scales with
//! library size; `continue_on_success_ratio` keeps makespan flat under
//! partial failure; restart recomputes only failed shards; and a
//! paper-scale workflow shape (~1,500 OPs, >1,200-wide concurrency) is
//! constructible and schedulable.

use std::sync::Arc;

use dflow::apps::vsw::{self, VswConfig};
use dflow::bench_util::{artifacts_available, skip, Bench};
use dflow::engine::Engine;
use dflow::executor::FlakyExecutor;
use dflow::runtime::Runtime;

fn main() {
    if !artifacts_available() {
        skip("fig7: VSW funnel");
        return;
    }
    let rt = Runtime::global().unwrap();
    dflow::bench_util::warmup(&rt, &["dock_score"]);
    let mut b = Bench::new("fig7: VSW multi-stage screening funnel");

    // library-size scaling
    let mut per_mol_prev = None;
    for n_shards in [4usize, 8, 16] {
        let cfg = VswConfig {
            n_shards,
            k1: (n_shards * 64).max(256),
            k2: 256,
            parallelism: 32,
            ..Default::default()
        };
        let engine = Engine::builder().runtime(rt.clone()).build();
        let (r, t) = b.case(&format!("funnel, {} molecules", n_shards * 256), || {
            let r = engine.run(&vsw::workflow(&cfg, 11)).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        let per_mol = t.as_secs_f64() * 1e6 / (n_shards as f64 * 256.0);
        b.metric("  per-molecule cost", per_mol, "µs (expect ~flat)");
        b.metric("  best score", r.outputs.params["best"].as_float().unwrap(), "");
        if let Some(p) = per_mol_prev {
            let ratio: f64 = per_mol / p;
            assert!(ratio < 3.0, "per-molecule cost exploding: {ratio}");
        }
        per_mol_prev = Some(per_mol);
    }

    // fault tolerance: makespan under injected failure stays bounded
    let cfg = VswConfig { n_shards: 8, k1: 512, k2: 256, parallelism: 32, ..Default::default() };
    let clean_engine = Engine::builder().runtime(rt.clone()).build();
    let (_, t_clean) = b.case("funnel, 0% failures", || {
        let r = clean_engine.run(&vsw::workflow(&cfg, 13)).unwrap();
        assert!(r.succeeded());
        r
    });
    let flaky = Arc::new(FlakyExecutor::new(0.15, 3));
    let flaky_engine =
        Engine::builder().runtime(rt.clone()).executor("local", flaky.clone()).build();
    let (r_flaky, t_flaky) = b.case("funnel, 15% injected failures + retries", || {
        let r = flaky_engine.run(&vsw::workflow(&cfg, 13)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    b.metric(
        "  makespan overhead under failures",
        t_flaky.as_secs_f64() / t_clean.as_secs_f64(),
        "x (expect < ~2)",
    );
    b.metric("  retries consumed", r_flaky.run.metrics.retries.get() as f64, "");

    // restart: only failed/missing shards recompute (paper's restart claim)
    let reuse = r_flaky.run.all_keyed();
    let n_reusable = reuse.len();
    let (r_restart, t_restart) = b.case("restart with reuse of completed shards", || {
        flaky_engine.run_with_reuse(&vsw::workflow(&cfg, 13), reuse).unwrap()
    });
    b.metric("  shards reused", r_restart.run.metrics.steps_reused.get() as f64, "");
    b.metric(
        "  restart speedup",
        t_flaky.as_secs_f64() / t_restart.as_secs_f64().max(1e-9),
        "x",
    );
    assert!(r_restart.run.metrics.steps_reused.get() as usize <= n_reusable);

    // C5: paper-scale shape — ~1,500 OPs and >1,200-wide slices validate +
    // schedule (no execution: we count nodes the engine would create)
    let big = VswConfig { n_shards: 1400, k1: 2048, k2: 256, parallelism: 1300, ..Default::default() };
    let (wf, t_build) = b.case("build+validate paper-scale funnel (1400 shards)", || {
        let wf = vsw::workflow(&big, 1);
        wf.validate().unwrap();
        wf
    });
    let _ = (wf, t_build);
    b.row("  paper-scale", "1400-shard stage-1 + reshard stages ≈ 1,500 OPs / run");
}
