//! C1 — "Dflow ... can scale to thousands of concurrent nodes per
//! workflow" (abstract). Slice fan-out ramp with trivially-small OPs over a
//! large simulated cluster; the interesting numbers are the per-step
//! scheduler overhead (should stay flat) and the peak concurrency actually
//! achieved (should track min(width, parallelism, cluster)).
//!
//! The closer is the 100k-node DAG: the whole graph multiplexes onto an
//! 8-worker pool with OS threads bounded by pool size + a constant, and
//! ready successors wake in batches (submit_batches << jobs_submitted).
//! `make bench-snapshot` checks the rendered rows into `BENCH_sched.json`
//! for regression diffing; `BENCH_SMOKE=1` (`make bench-smoke`) shrinks
//! every case to an assert-only pass and writes no snapshot.
//!
//! No AOT artifacts needed — this isolates the L3 coordinator.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use dflow::bench_util::{diamond_chain_workflow, Bench};
use dflow::cluster::{Cluster, Resources};
use dflow::core::{
    ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::Engine;

fn fan_workflow(width: usize, parallelism: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    Workflow::new("fan")
        .container(ContainerTemplate::new("op", op).resources(Resources::cpu(100)))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").parallelism(parallelism)),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main")
}

fn sleepy_fan_workflow(width: usize, parallelism: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(20));
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    Workflow::new("fan")
        .container(ContainerTemplate::new("op", op).resources(Resources::cpu(100)))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").parallelism(parallelism)),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main")
}

/// Current OS thread count of this process (`/proc/self/status`); 0 when
/// the proc filesystem is unavailable (non-Linux).
fn os_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let mut b = Bench::new("c1: scalability — slice fan-out ramp (no-op payload)");

    // engine-only (no cluster): raw coordinator throughput
    let widths: &[usize] = if smoke { &[100, 500] } else { &[100, 500, 1000, 5000] };
    for &width in widths {
        let engine = Engine::builder().parallelism(256).build();
        let wf = fan_workflow(width, 256);
        let (r, t) = b.case(&format!("engine only, width {width}"), || {
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        let per_step = t.as_secs_f64() * 1e6 / width as f64;
        b.metric("  coordinator cost/step", per_step, "µs (expect ~flat)");
        assert_eq!(r.outputs.params["os"].as_list().unwrap().len(), width);
    }

    // with the cluster simulator: thousands of pods through bin-packing.
    // the payload sleeps 20ms (latency-bound, like a remote job) so
    // hundreds of pods are genuinely concurrent even on one core
    let widths: &[usize] = if smoke { &[512] } else { &[1000, 2000] };
    for &width in widths {
        // 128 nodes x 4 slots of 100 mCPU = 512 concurrent pods
        let cluster = Arc::new(Cluster::uniform(128, Resources::cpu(400), 1));
        let engine = Engine::builder().cluster(cluster.clone()).parallelism(512).build();
        let wf = sleepy_fan_workflow(width, 512);
        let (_, t) = b.case(&format!("with cluster sim, width {width}"), || {
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        let (bound, released, peak) = cluster.stats();
        assert_eq!(bound, width as u64);
        assert_eq!(released, width as u64);
        b.metric("  pods scheduled", bound as f64, "");
        b.metric("  peak concurrent pods", peak as f64, "(cluster cap 512)");
        assert!(peak >= 128, "concurrency did not materialize: {peak}");
        // ideal makespan = width x 20ms / 512 concurrent
        let ideal = width as f64 * 0.020 / 512.0;
        b.metric("  makespan vs ideal", t.as_secs_f64() / ideal, "x (expect ~1-2)");
        b.metric("  pod schedule+run cost", t.as_secs_f64() * 1e6 / width as f64, "µs/pod");
    }

    // deep recursion: a 200-iteration dynamic loop (serial scaling)
    let inc = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("next", ParamType::Int),
        |ctx| {
            ctx.set("next", ctx.get_int("i")? + 1);
            Ok(())
        },
    ));
    let wf = Workflow::new("deep")
        .container(ContainerTemplate::new("inc", inc))
        .steps(
            Steps::new("loop")
                .signature(Signature::new().in_param("i", ParamType::Int))
                .then(Step::new("body", "inc").param_from_input("i", "i"))
                .then(
                    Step::new("again", "loop")
                        .param_from_step("i", "body", "next")
                        .when(dflow::core::Expr::lt(
                            dflow::core::Operand::StepOutput {
                                step: "body".into(),
                                name: "next".into(),
                            },
                            dflow::core::Operand::Const(Value::Int(200)),
                        )),
                ),
        )
        .entrypoint("loop")
        .arg("i", 0i64);
    let engine = Engine::local();
    let (r, t) = b.case("200-deep recursive loop", || {
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    b.metric("  cost per iteration", t.as_secs_f64() * 1e6 / 200.0, "µs");
    assert_eq!(
        r.run
            .nodes()
            .iter()
            .filter(|n| n.path.ends_with("/body"))
            .count(),
        200
    );

    // 2000-node diamond-chain DAG on the bounded step scheduler: the whole
    // dependency graph multiplexes onto `parallelism` pool workers instead
    // of one thread per ready task
    for parallelism in [8usize, 64] {
        let (wf, probe, nodes) = diamond_chain_workflow(2002, parallelism);
        let engine = Engine::builder().parallelism(parallelism).build();
        let (r, t) = b.case(
            &format!("{nodes}-node dag chain, pool {parallelism}"),
            || {
                let r = engine.run(&wf).unwrap();
                assert!(r.succeeded(), "{:?}", r.error);
                r
            },
        );
        assert_eq!(r.run.nodes().len(), nodes);
        assert!(
            probe.peak() <= parallelism,
            "peak {} exceeds pool {parallelism}",
            probe.peak()
        );
        b.metric("  peak live workers", probe.peak() as f64, &format!("(cap {parallelism})"));
        b.metric("  scheduler cost/task", t.as_secs_f64() * 1e6 / nodes as f64, "µs");
    }

    // the 100k-node closer: one DAG, 8 pool workers, OS threads bounded by
    // pool size + a constant (no thread-per-task, no thread-per-deadline),
    // successors woken in batches (one queue lock per completion, not one
    // per ready task)
    {
        let target = if smoke { 2_002 } else { 100_002 };
        let pool = 8usize;
        let (wf, probe, nodes) = diamond_chain_workflow(target, pool);
        let engine = Engine::builder().parallelism(pool).build();

        // sample the process thread count while the DAG runs: the bound
        // must hold mid-flight, not just after the pool drains
        let base_threads = os_threads();
        let peak_threads = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let sampler = {
            let (peak, stop) = (Arc::clone(&peak_threads), Arc::clone(&stop));
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    peak.fetch_max(os_threads(), Ordering::Relaxed);
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            })
        };
        let (r, t) = b.case(&format!("{nodes}-node dag, pool {pool}"), || {
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        stop.store(true, Ordering::Relaxed);
        sampler.join().unwrap();

        assert_eq!(r.run.nodes().len(), nodes);
        assert!(probe.peak() <= pool, "peak {} exceeds pool {pool}", probe.peak());
        b.metric("  scheduler cost/task", t.as_secs_f64() * 1e6 / nodes as f64, "µs");

        let peak = peak_threads.load(Ordering::Relaxed);
        b.metric("  peak OS threads", peak as f64, &format!("(baseline {base_threads})"));
        if peak > 0 {
            // pool workers + main + sampler + timer/appender slack: the
            // graph is 100k nodes, the thread budget is a constant
            assert!(
                peak <= base_threads + pool + 8,
                "thread count must not scale with graph size: \
                 peak {peak} vs baseline {base_threads} + pool {pool}"
            );
        }

        let stats = engine.scheduler_stats();
        assert!(stats.jobs_submitted >= nodes as u64, "every task goes through the pool");
        assert!(
            stats.submit_batches < stats.jobs_submitted,
            "wakeups must coalesce: {} batches for {} jobs",
            stats.submit_batches,
            stats.jobs_submitted
        );
        b.metric(
            "  jobs per queue wakeup",
            stats.jobs_submitted as f64 / stats.submit_batches.max(1) as f64,
            "jobs/batch (>1 = coalesced)",
        );
    }

    if !smoke {
        Bench::write_snapshot("BENCH_sched.json", &[&b]).unwrap();
    }
}
