//! C1 — "Dflow ... can scale to thousands of concurrent nodes per
//! workflow" (abstract). Slice fan-out ramp with trivially-small OPs over a
//! large simulated cluster; the interesting numbers are the per-step
//! scheduler overhead (should stay flat) and the peak concurrency actually
//! achieved (should track min(width, parallelism, cluster)).
//!
//! No AOT artifacts needed — this isolates the L3 coordinator.

use std::sync::Arc;

use dflow::bench_util::{diamond_chain_workflow, Bench};
use dflow::cluster::{Cluster, Resources};
use dflow::core::{
    ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::Engine;

fn fan_workflow(width: usize, parallelism: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    Workflow::new("fan")
        .container(ContainerTemplate::new("op", op).resources(Resources::cpu(100)))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").parallelism(parallelism)),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main")
}

fn sleepy_fan_workflow(width: usize, parallelism: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(20));
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    Workflow::new("fan")
        .container(ContainerTemplate::new("op", op).resources(Resources::cpu(100)))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").parallelism(parallelism)),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main")
}

fn main() {
    let mut b = Bench::new("c1: scalability — slice fan-out ramp (no-op payload)");

    // engine-only (no cluster): raw coordinator throughput
    for width in [100usize, 500, 1000, 5000] {
        let engine = Engine::builder().parallelism(256).build();
        let wf = fan_workflow(width, 256);
        let (r, t) = b.case(&format!("engine only, width {width}"), || {
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        let per_step = t.as_secs_f64() * 1e6 / width as f64;
        b.metric("  coordinator cost/step", per_step, "µs (expect ~flat)");
        assert_eq!(r.outputs.params["os"].as_list().unwrap().len(), width);
    }

    // with the cluster simulator: thousands of pods through bin-packing.
    // the payload sleeps 20ms (latency-bound, like a remote job) so
    // hundreds of pods are genuinely concurrent even on one core
    for width in [1000usize, 2000] {
        // 128 nodes x 4 slots of 100 mCPU = 512 concurrent pods
        let cluster = Arc::new(Cluster::uniform(128, Resources::cpu(400), 1));
        let engine = Engine::builder().cluster(cluster.clone()).parallelism(512).build();
        let wf = sleepy_fan_workflow(width, 512);
        let (_, t) = b.case(&format!("with cluster sim, width {width}"), || {
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        let (bound, released, peak) = cluster.stats();
        assert_eq!(bound, width as u64);
        assert_eq!(released, width as u64);
        b.metric("  pods scheduled", bound as f64, "");
        b.metric("  peak concurrent pods", peak as f64, "(cluster cap 512)");
        assert!(peak >= 128, "concurrency did not materialize: {peak}");
        // ideal makespan = width x 20ms / 512 concurrent
        let ideal = width as f64 * 0.020 / 512.0;
        b.metric("  makespan vs ideal", t.as_secs_f64() / ideal, "x (expect ~1-2)");
        b.metric("  pod schedule+run cost", t.as_secs_f64() * 1e6 / width as f64, "µs/pod");
    }

    // deep recursion: a 200-iteration dynamic loop (serial scaling)
    let inc = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("next", ParamType::Int),
        |ctx| {
            ctx.set("next", ctx.get_int("i")? + 1);
            Ok(())
        },
    ));
    let wf = Workflow::new("deep")
        .container(ContainerTemplate::new("inc", inc))
        .steps(
            Steps::new("loop")
                .signature(Signature::new().in_param("i", ParamType::Int))
                .then(Step::new("body", "inc").param_from_input("i", "i"))
                .then(
                    Step::new("again", "loop")
                        .param_from_step("i", "body", "next")
                        .when(dflow::core::Expr::lt(
                            dflow::core::Operand::StepOutput {
                                step: "body".into(),
                                name: "next".into(),
                            },
                            dflow::core::Operand::Const(Value::Int(200)),
                        )),
                ),
        )
        .entrypoint("loop")
        .arg("i", 0i64);
    let engine = Engine::local();
    let (r, t) = b.case("200-deep recursive loop", || {
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    b.metric("  cost per iteration", t.as_secs_f64() * 1e6 / 200.0, "µs");
    assert_eq!(
        r.run
            .nodes()
            .iter()
            .filter(|n| n.path.ends_with("/body"))
            .count(),
        200
    );

    // 2000-node diamond-chain DAG on the bounded step scheduler: the whole
    // dependency graph multiplexes onto `parallelism` pool workers instead
    // of one thread per ready task
    for parallelism in [8usize, 64] {
        let (wf, probe, nodes) = diamond_chain_workflow(2002, parallelism);
        let engine = Engine::builder().parallelism(parallelism).build();
        let (r, t) = b.case(
            &format!("{nodes}-node dag chain, pool {parallelism}"),
            || {
                let r = engine.run(&wf).unwrap();
                assert!(r.succeeded(), "{:?}", r.error);
                r
            },
        );
        assert_eq!(r.run.nodes().len(), nodes);
        assert!(
            probe.peak() <= parallelism,
            "peak {} exceeds pool {parallelism}",
            probe.peak()
        );
        b.metric("  peak live workers", probe.peak() as f64, &format!("(cap {parallelism})"));
        b.metric("  scheduler cost/task", t.as_secs_f64() * 1e6 / nodes as f64, "µs");
    }
}
