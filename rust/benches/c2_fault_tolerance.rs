//! C2 — §2.4 fault-tolerance policies: retries on transient errors,
//! timeouts, and `continue_on` thresholds under swept failure rates.
//!
//! Expected shape: success probability of the whole workflow stays ~1 while
//! the per-attempt failure rate rises (retries absorb it), at a makespan
//! overhead ≈ 1/(1-p) per affected step; success-ratio policies keep sliced
//! steps alive with zero retry cost.

use std::sync::Arc;

use dflow::bench_util::Bench;
use dflow::core::{
    ContainerTemplate, ContinueOn, FnOp, OpError, ParamType, Signature, Slices, Step,
    StepPolicy, Steps, Value, Workflow,
};
use dflow::engine::Engine;
use dflow::executor::FlakyExecutor;

fn sliced_workflow(width: usize, policy: StepPolicy, continue_on: Option<ContinueOn>) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    let mut slices = Slices::over("i").stack("o").parallelism(32);
    if let Some(c) = continue_on {
        slices = slices.continue_on(c);
    }
    Workflow::new("ft")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(
            Step::new("fan", "op")
                .param("i", Value::ints(0..width as i64))
                .slices(slices)
                .policy(policy),
        ))
        .entrypoint("main")
}

fn main() {
    let mut b = Bench::new("c2: fault tolerance — retries / ratios / timeouts");
    let width = 200usize;

    // baseline
    let engine = Engine::local();
    let (_, t0) = b.case("0% failure baseline", || {
        let r = engine.run(&sliced_workflow(width, StepPolicy::default(), None)).unwrap();
        assert!(r.succeeded());
    });

    // retries absorb rising transient-failure rates
    for rate in [0.1f64, 0.3, 0.5] {
        let flaky = Arc::new(FlakyExecutor::new(rate, 99));
        let engine = Engine::builder().executor("local", flaky.clone()).build();
        let mut policy = StepPolicy::default();
        policy.retries = 25;
        let (r, t) = b.case(&format!("{:.0}% transient failures + retries", rate * 100.0), || {
            let r = engine.run(&sliced_workflow(width, policy.clone(), None)).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        // with spare slice parallelism, retries fill scheduling slack and
        // the makespan stays ~flat; a fully-loaded serial system would pay
        // ~1/(1-p)
        b.metric("  retries consumed", r.run.metrics.retries.get() as f64, "");
        b.metric(
            "  makespan overhead",
            t.as_secs_f64() / t0.as_secs_f64(),
            "x (expect ~1 with spare parallelism)",
        );
    }

    // success-ratio policy: fatal failures tolerated with zero retries
    let fatal_op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            let i = ctx.get_int("i")?;
            if i % 4 == 0 {
                return Err(OpError::Fatal("hard shard failure".into()));
            }
            ctx.set("o", i);
            Ok(())
        },
    ));
    let wf = Workflow::new("ratio")
        .container(ContainerTemplate::new("op", fatal_op))
        .steps(Steps::new("main").then(
            Step::new("fan", "op")
                .param("i", Value::ints(0..width as i64))
                .slices(
                    Slices::over("i")
                        .stack("o")
                        .parallelism(32)
                        .continue_on(ContinueOn::SuccessRatio(0.7)),
                ),
        ))
        .entrypoint("main");
    let engine = Engine::local();
    let (r, _) = b.case("25% fatal failures, success_ratio=0.7", || {
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    b.metric("  slices failed (tolerated)", r.run.metrics.steps_failed.get() as f64, "");
    assert_eq!(r.run.metrics.retries.get(), 0);

    // timeout policy: slow steps killed and (optionally) retried
    let slow_once = {
        let hit = Arc::new(std::sync::atomic::AtomicU32::new(0));
        Arc::new(FnOp::new(
            Signature::new().out_param("ok", ParamType::Bool),
            move |ctx| {
                if hit.fetch_add(1, std::sync::atomic::Ordering::SeqCst) == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(500));
                }
                ctx.set("ok", true);
                Ok(())
            },
        ))
    };
    let mut policy = StepPolicy::default();
    policy.timeout = Some(std::time::Duration::from_millis(50));
    policy.timeout_transient = true;
    policy.retries = 2;
    let wf = Workflow::new("timeout")
        .container(ContainerTemplate::new("op", slow_once))
        .steps(Steps::new("main").then(Step::new("s", "op").policy(policy)))
        .entrypoint("main");
    let (r, _) = b.case("timeout kill + retry succeeds", || {
        let r = Engine::local().run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    b.metric("  timeouts fired", r.run.metrics.timeouts.get() as f64, "(expect 1)");
}
