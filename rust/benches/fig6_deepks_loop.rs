//! F6 — Fig. 6 DeePKS SCF ⇄ TRAIN loop with fault-tolerant SCF slices.
//!
//! Expected shape: the loop completes the same number of iterations as the
//! SCF failure rate rises from 0% to 30% (the `continue_on_success_ratio`
//! policy absorbs divergent SCF tasks), with makespan roughly flat — the
//! paper's "a certain proportion of SCF calculations [may] fail without
//! affecting the overall process".

use dflow::apps::deepks::{self, DeepksConfig};
use dflow::bench_util::{artifacts_available, skip, Bench};
use dflow::core::Value;
use dflow::engine::Engine;
use dflow::runtime::Runtime;

fn main() {
    if !artifacts_available() {
        skip("fig6: DeePKS loop");
        return;
    }
    let rt = Runtime::global().unwrap();
    dflow::bench_util::warmup(&rt, &["lj_ef", "train_step"]);
    let engine = Engine::builder().runtime(rt).build();
    let mut b = Bench::new("fig6: DeePKS SCF<->TRAIN loop under SCF failures");

    // NOTE: the SCF op's fail_rate default is 0.1; we rebuild the workflow
    // with the rate folded into the template default by overriding per run.
    // untimed warm run (first engine run pays one-off allocation/compile)
    {
        let cfg = DeepksConfig { n_systems: 2, train_steps: 5, max_iters: 1, ..Default::default() };
        let _ = engine.run(&deepks::workflow(&cfg)).unwrap();
    }
    let mut baseline = None;
    for fail_pct in [0.0f64, 0.1, 0.3] {
        let cfg = DeepksConfig {
            n_systems: 8,
            scf_success_ratio: 0.5,
            train_steps: 60,
            conv_loss: 1e-9, // force max_iters iterations
            max_iters: 2,
            ..Default::default()
        };
        let mut wf = deepks::workflow(&cfg);
        // thread the failure rate through the scf step's default param
        if let Some(dflow::core::OpTemplate::Steps(s)) = wf.templates.get_mut("deepks-scf") {
            for g in &mut s.groups {
                for step in g {
                    if step.name == "run-scf" {
                        step.parameters
                            .insert("fail_rate".into(), Value::Float(fail_pct).into());
                    }
                }
            }
        }
        let (r, t) = b.case(&format!("loop, SCF divergence rate {:.0}%", fail_pct * 100.0), || {
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        // both iterations trained despite failures
        assert!(r.run.query_step("train-0").is_some());
        assert!(r.run.query_step("train-1").is_some());
        let failed = r.run.metrics.steps_failed.get();
        b.metric("  SCF slices failed", failed as f64, "");
        let loss = r.run.query_step("train-1").unwrap().outputs.params["final_loss"]
            .as_float()
            .unwrap();
        b.metric("  final loss (iter 1)", loss, "");
        match baseline {
            None => baseline = Some(t.as_secs_f64()),
            Some(t0) => b.metric("  makespan vs 0% rate", t.as_secs_f64() / t0, "x (expect ~1)"),
        }
    }

    // convergence path: a loose threshold stops the loop early
    let cfg = DeepksConfig {
        n_systems: 6,
        scf_success_ratio: 0.5, // the op's default 10% divergence stays tolerable
        train_steps: 120,
        conv_loss: 1e3, // converges after iteration 0
        max_iters: 4,
        ..Default::default()
    };
    let (r, _) = b.case("loop with early convergence", || {
        let r = engine.run(&deepks::workflow(&cfg)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    assert!(r.run.query_step("train-0").is_some());
    assert!(r.run.query_step("train-1").is_none(), "breaking condition ignored");
    b.row("dynamic breaking condition", "stopped after iteration 0 (loss < threshold)");
}
