//! C3 — §2.5 restart/reuse: speedup of resubmission vs cold run as the
//! reusable fraction grows, plus the modify-outputs path.
//!
//! Expected shape: warm makespan ≈ (1 - reuse_fraction) x cold makespan
//! (reuse lookups are ~free next to step bodies).

use std::sync::Arc;

use dflow::bench_util::Bench;
use dflow::core::{
    ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::{Engine, ReusedStep, StepOutputs};

fn expensive_workflow(width: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            ctx.set("o", ctx.get_int("i")? * 10)
                ;
            Ok(())
        },
    ));
    Workflow::new("exp")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").parallelism(8))
                        .key("step-{{item}}"),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main")
}

fn main() {
    let mut b = Bench::new("c3: restart/reuse — warm-start speedups");
    let width = 64usize;
    let engine = Engine::local();
    let wf = expensive_workflow(width);

    let (r_cold, t_cold) = b.case("cold run (64 x 5ms steps, parallelism 8)", || {
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });

    for frac in [0.25f64, 0.5, 0.75, 1.0] {
        let keep = (width as f64 * frac) as usize;
        let reuse: Vec<ReusedStep> =
            r_cold.run.all_keyed().into_iter().take(keep).collect();
        let (r, t) = b.case(&format!("warm run, {:.0}% reusable", frac * 100.0), || {
            let r = engine.run_with_reuse(&wf, reuse.clone()).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        assert_eq!(r.run.metrics.steps_reused.get() as usize, keep);
        let ideal = 1.0 / (1.0 - frac + 1e-3);
        b.metric(
            "  speedup",
            t_cold.as_secs_f64() / t.as_secs_f64().max(1e-9),
            &format!("x (ideal ~{ideal:.1})"),
        );
    }

    // modify_output_parameter before reuse (paper: fix up results, resume)
    let patched: Vec<ReusedStep> = r_cold
        .run
        .all_keyed()
        .into_iter()
        .map(|r| {
            if r.key == "step-0" {
                r.modify_output_parameter("o", 9999i64)
            } else {
                r
            }
        })
        .collect();
    let (r, _) = b.case("reuse with modified outputs", || {
        engine.run_with_reuse(&wf, patched.clone()).unwrap()
    });
    assert_eq!(
        r.outputs.params["os"].as_list().unwrap()[0],
        Value::Int(9999),
        "modified output did not propagate"
    );
    b.row("  modify_output_parameter", "patched value propagated downstream");

    // reuse-lookup microcost
    let mut out = StepOutputs::default();
    out.params.insert("o".into(), Value::Int(1));
    let reuse_all: Vec<ReusedStep> =
        (0..width).map(|i| ReusedStep::new(format!("step-{i}"), out.clone())).collect();
    b.case_n("full-reuse run (lookup cost only)", 10, || {
        engine.run_with_reuse(&wf, reuse_all.clone()).unwrap()
    });
}
