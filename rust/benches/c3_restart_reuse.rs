//! C3 — §2.5 restart/reuse: speedup of resubmission vs cold run as the
//! reusable fraction grows, plus the modify-outputs path and the
//! artifact-forwarding reuse path (plain byte copies vs CAS ref-bumps).
//!
//! Expected shape: warm makespan ≈ (1 - reuse_fraction) x cold makespan
//! (reuse lookups are ~free next to step bodies), and the artifact-heavy
//! warm run collapses further over CAS storage because forwarding a reused
//! artifact re-writes a ~100-byte manifest instead of copying megabytes.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dflow::bench_util::Bench;
use dflow::core::{
    ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::{Engine, ReusedStep, StepOutputs};
use dflow::storage::{CasStore, LocalStorage, StorageClient};
use dflow::util::Rng;

fn expensive_workflow(width: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            ctx.set("o", ctx.get_int("i")? * 10)
                ;
            Ok(())
        },
    ));
    Workflow::new("exp")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").parallelism(8))
                        .key("step-{{item}}"),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main")
}

fn main() {
    let mut b = Bench::new("c3: restart/reuse — warm-start speedups");
    let width = 64usize;
    let engine = Engine::local();
    let wf = expensive_workflow(width);

    let (r_cold, t_cold) = b.case("cold run (64 x 5ms steps, parallelism 8)", || {
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });

    for frac in [0.25f64, 0.5, 0.75, 1.0] {
        let keep = (width as f64 * frac) as usize;
        let reuse: Vec<ReusedStep> =
            r_cold.run.all_keyed().into_iter().take(keep).collect();
        let (r, t) = b.case(&format!("warm run, {:.0}% reusable", frac * 100.0), || {
            let r = engine.run_with_reuse(&wf, reuse.clone()).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        assert_eq!(r.run.metrics.steps_reused.get() as usize, keep);
        let ideal = 1.0 / (1.0 - frac + 1e-3);
        b.metric(
            "  speedup",
            t_cold.as_secs_f64() / t.as_secs_f64().max(1e-9),
            &format!("x (ideal ~{ideal:.1})"),
        );
    }

    // modify_output_parameter before reuse (paper: fix up results, resume)
    let patched: Vec<ReusedStep> = r_cold
        .run
        .all_keyed()
        .into_iter()
        .map(|r| {
            if r.key == "step-0" {
                r.modify_output_parameter("o", 9999i64)
            } else {
                r
            }
        })
        .collect();
    let (r, _) = b.case("reuse with modified outputs", || {
        engine.run_with_reuse(&wf, patched.clone()).unwrap()
    });
    assert_eq!(
        r.outputs.params["os"].as_list().unwrap()[0],
        Value::Int(9999),
        "modified output did not propagate"
    );
    b.row("  modify_output_parameter", "patched value propagated downstream");

    // reuse-lookup microcost
    let mut out = StepOutputs::default();
    out.params.insert("o".into(), Value::Int(1));
    let reuse_all: Vec<ReusedStep> =
        (0..width).map(|i| ReusedStep::new(format!("step-{i}"), out.clone())).collect();
    b.case_n("full-reuse run (lookup cost only)", 10, || {
        engine.run_with_reuse(&wf, reuse_all.clone()).unwrap()
    });

    // -- artifact forwarding: byte copies vs CAS ref-bumps ------------------
    // each keyed slice emits a 2 MiB artifact; the engine stacks them
    // (its copy_with_retry forwarding path). A full-reuse warm run does no
    // OP work — its makespan is pure artifact forwarding, so it measures
    // plain server-side byte copies against CAS manifest ref-bumps.
    const MB: usize = 1024 * 1024;
    let art_width = 8usize;
    fn artifact_workflow(width: usize, mb: usize) -> Workflow {
        let op = Arc::new(FnOp::new(
            Signature::new().in_param("i", ParamType::Int).out_artifact("blob"),
            move |ctx| {
                let i = ctx.get_int("i")?;
                let mut rng = Rng::new(7000 + i as u64);
                let data: Vec<u8> = (0..2 * mb).map(|_| rng.next_u64() as u8).collect();
                ctx.write_artifact("blob", &data)?;
                Ok(())
            },
        ));
        Workflow::new("art")
            .container(ContainerTemplate::new("op", op))
            .steps(
                Steps::new("main")
                    .then(
                        Step::new("fan", "op")
                            .param("i", Value::ints(0..width as i64))
                            .slices(Slices::over("i").stack_artifact("blob").parallelism(4))
                            .key("art-{{item}}"),
                    )
                    .out_artifact_from("blobs", "fan", "blob"),
            )
            .entrypoint("main")
    }

    let plain_dir = std::env::temp_dir().join(format!("c3-plain-{}", dflow::util::next_id()));
    let cas_dir = std::env::temp_dir().join(format!("c3-cas-{}", dflow::util::next_id()));
    let plain: Arc<dyn StorageClient> = Arc::new(LocalStorage::new(&plain_dir).unwrap());
    let cas = Arc::new(CasStore::new(
        Arc::new(LocalStorage::new(&cas_dir).unwrap()) as Arc<dyn StorageClient>
    ));
    let wf_art = artifact_workflow(art_width, MB);

    let mut warm_times = Vec::new();
    for (label, storage) in [
        ("plain local (byte copies)", plain.clone()),
        ("cas over local (ref bumps)", cas.clone() as Arc<dyn StorageClient>),
    ] {
        let engine = Engine::builder().storage(storage).build();
        let (cold, _t_cold) = b.case(
            &format!("artifact fan-out cold ({art_width} x 2MiB) — {label}"),
            || {
                let r = engine.run(&wf_art).unwrap();
                assert!(r.succeeded(), "{:?}", r.error);
                r
            },
        );
        let reuse = cold.run.all_keyed();
        let (warm, t_warm) = b.case(
            &format!("artifact fan-out warm, 100% reuse — {label}"),
            || {
                let r = engine.run_with_reuse(&wf_art, reuse.clone()).unwrap();
                assert!(r.succeeded(), "{:?}", r.error);
                r
            },
        );
        assert_eq!(warm.run.metrics.steps_reused.get() as usize, art_width);
        warm_times.push(t_warm);
    }
    let c = cas.counters();
    // zero-copy invariant: across cold+warm the only chunk bodies stored
    // are the 8 cold slice writes — copies/forwarding moved nothing
    assert_eq!(
        c.chunk_put_bytes.load(Ordering::Relaxed),
        (art_width * 2 * MB) as u64,
        "CAS forwarding must move zero data bytes"
    );
    assert_eq!(c.chunk_gets.load(Ordering::Relaxed), 0, "forwarding must download nothing");
    b.metric(
        "  warm forwarding speedup (cas vs plain)",
        warm_times[0].as_secs_f64() / warm_times[1].as_secs_f64().max(1e-9),
        "x",
    );
    b.row(
        "  cas counters",
        &format!(
            "chunk_puts={} chunk_put_bytes={} chunk_gets={} dedup_hits={}",
            c.chunk_puts.load(Ordering::Relaxed),
            c.chunk_put_bytes.load(Ordering::Relaxed),
            c.chunk_gets.load(Ordering::Relaxed),
            c.dedup_hits.load(Ordering::Relaxed),
        ),
    );
    std::fs::remove_dir_all(plain_dir).ok();
    std::fs::remove_dir_all(cas_dir).ok();
}
