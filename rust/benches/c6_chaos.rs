//! C6 — failover recovery latency: a backend dies under a saturated
//! fan-out and the battery measures what fault tolerance costs — wall
//! clock vs an undisturbed baseline, how many in-flight attempts were
//! voided, and (from journal timestamps) how long each voided attempt
//! took to re-place on a surviving backend.
//!
//! `make bench-snapshot` runs this and checks the rendered rows into
//! `BENCH_chaos.json` for regression diffing; `BENCH_SMOKE=1`
//! (`make bench-smoke`) shrinks the fan-out to an assert-only pass and
//! writes no snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dflow::bench_util::Bench;
use dflow::check::chaos::{ChaosAction, ChaosPlan};
use dflow::core::{
    ContainerTemplate, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::{Backend, Engine};
use dflow::journal::{Journal, JournalEvent};
use dflow::storage::{MemStorage, StorageClient};

fn fanout(width: i64, work: Duration) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        move |ctx| {
            std::thread::sleep(work);
            ctx.set("y", ctx.get_int("x")? * 2);
            Ok(())
        },
    ));
    Workflow::new("c6-fanout")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main").then(
                Step::new("fan", "op")
                    .param("x", Value::ints(0..width))
                    .slices(Slices::over("x").stack("y").parallelism(64)),
            ),
        )
        .entrypoint("main")
}

fn tri_backend_engine(journal: Option<Arc<Journal>>) -> Engine {
    let mut builder = Engine::builder()
        .backend(Backend::local_slots("b0", 8))
        .backend(Backend::local_slots("b1", 8))
        .backend(Backend::local_slots("b2", 8))
        .parallelism(24);
    if let Some(j) = journal {
        builder = builder.journal(j);
    }
    builder.build()
}

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let mut b = Bench::new("c6: chaos — failover recovery latency");
    let width = if smoke { 150i64 } else { 600 };
    // keep the kill mid-run at either scale so attempts are in flight
    let kill_at = if smoke { 75u64 } else { 500 };
    let work = Duration::from_millis(1);

    // undisturbed baseline: same fan-out, same backends, nobody dies
    let (r_base, t_base) = b.case(&format!("{width}-slice fan-out, no faults"), || {
        tri_backend_engine(None).run(&fanout(width, work)).unwrap()
    });
    assert!(r_base.succeeded(), "{:?}", r_base.error);

    // chaos run: kill b0 (8 attempts in flight) at a mid-run boundary
    let storage: Arc<dyn StorageClient> = Arc::new(MemStorage::new());
    let journal = Arc::new(Journal::open(storage).unwrap());
    let engine = tri_backend_engine(Some(Arc::clone(&journal)));
    let plan = ChaosPlan::new();
    let b0 = Arc::clone(engine.placer().unwrap().backend("b0").unwrap());
    let killed_at = Arc::new(AtomicU64::new(0));
    let k2 = Arc::clone(&killed_at);
    plan.at(
        kill_at,
        ChaosAction::Call(Box::new(move || k2.store(dflow::util::epoch_ms(), Ordering::SeqCst))),
    );
    plan.at(kill_at, ChaosAction::KillBackend(Arc::clone(&b0)));
    plan.install(&engine);
    let (r, t_chaos) = b.case(&format!("{width}-slice fan-out, 1 of 3 backends killed"), || {
        engine.run(&fanout(width, work)).unwrap()
    });
    assert!(r.succeeded(), "failover must keep the run alive: {:?}", r.error);
    assert_eq!(plan.pending(), 0, "the kill never fired");

    let failovers = r.run.metrics.failovers.get();
    assert!(failovers >= 1, "a saturated backend died; attempts must have failed over");
    b.metric("in-flight attempts failed over", failovers as f64, "");
    b.metric(
        "failover wall-clock overhead",
        (t_chaos.as_secs_f64() / t_base.as_secs_f64() - 1.0) * 100.0,
        "% vs baseline",
    );

    // per-attempt recovery latency: journal time from each NodeFailedOver
    // to the same path's next NodePlaced on a surviving backend
    let (events, torn) = journal.events(r.run.id).unwrap();
    assert!(!torn);
    let mut recoveries = Vec::new();
    for (i, rec) in events.iter().enumerate() {
        if let JournalEvent::NodeFailedOver { path, .. } = &rec.event {
            let replaced = events[i + 1..].iter().find(|later| {
                matches!(&later.event,
                    JournalEvent::NodePlaced { path: p, backend, .. }
                        if p == path && backend != "b0")
            });
            if let Some(later) = replaced {
                recoveries.push(later.at_ms.saturating_sub(rec.at_ms));
            }
        }
    }
    assert_eq!(recoveries.len() as u64, failovers, "every voided attempt must re-place");
    let mean = recoveries.iter().sum::<u64>() as f64 / recoveries.len() as f64;
    let worst = recoveries.iter().copied().max().unwrap_or(0);
    b.metric("failover recovery latency (mean)", mean, "ms");
    b.metric("failover recovery latency (worst)", worst as f64, "ms");
    let end_ms = dflow::util::epoch_ms();
    b.metric(
        "kill -> run completion",
        end_ms.saturating_sub(killed_at.load(Ordering::SeqCst)) as f64,
        "ms",
    );

    if !smoke {
        Bench::write_snapshot("BENCH_chaos.json", &[&b]).unwrap();
    }
}
