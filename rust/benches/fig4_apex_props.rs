//! F4 — Fig. 4 APEX job types: relaxation, property, joint.
//!
//! Expected shape: property ≈ the dominant cost (concurrent FP tasks);
//! joint ≈ relaxation + property (streamlined, no manual handoff); the
//! computed properties are physically sane and consistent across job types.

use dflow::apps::apex;
use dflow::bench_util::{artifacts_available, skip, Bench};
use dflow::engine::Engine;
use dflow::runtime::Runtime;

fn main() {
    if !artifacts_available() {
        skip("fig4: APEX job types");
        return;
    }
    let rt = Runtime::global().unwrap();
    dflow::bench_util::warmup(&rt, &["lj_ef"]);
    let engine = Engine::builder().runtime(rt).build();
    let mut b = Bench::new("fig4: APEX relaxation / property / joint jobs");
    let scales = [0.85, 0.9, 0.95, 1.0, 1.05, 1.1, 1.15];

    let (r_relax, t_relax) = b.case("relaxation job", || {
        let r = engine.run(&apex::relaxation_workflow(3)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    b.metric("  relaxed energy", r_relax.outputs.params["energy"].as_float().unwrap(), "eps");
    b.metric("  residual fmax", r_relax.outputs.params["fmax"].as_float().unwrap(), "eps/sigma");

    // property job consumes the relaxation's output artifact
    let relaxed = r_relax.outputs.artifacts["relaxed"].clone();
    let (r_prop, t_prop) = b.case("property job (concurrent DAG)", || {
        let wf = apex::property_workflow(&scales).input_artifact("relaxed", relaxed.clone());
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    for key in ["v0", "e0", "b0", "e_cohesive"] {
        b.metric(&format!("  {key}"), r_prop.outputs.params[key].as_float().unwrap(), "");
    }

    let (r_joint, t_joint) = b.case("joint job (relax + property)", || {
        let r = engine.run(&apex::joint_workflow(3, &scales)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    // joint must agree with property-after-relaxation
    let d = (r_joint.outputs.params["e_cohesive"].as_float().unwrap()
        - r_prop.outputs.params["e_cohesive"].as_float().unwrap())
    .abs();
    b.metric("joint vs staged e_cohesive delta", d, "(expect ~0)");
    b.metric(
        "joint ~= relax + property (time ratio)",
        t_joint.as_secs_f64() / (t_relax + t_prop).as_secs_f64(),
        "(expect <= ~1)",
    );
    assert!(d < 0.5, "job types disagree: {d}");
}
