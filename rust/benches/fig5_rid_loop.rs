//! F5 — Fig. 5 Rid-kit Block loop: Exploration → Selection → Labeling →
//! Training, with the paper's parallelism knobs (labeling default 10,
//! training default 4).
//!
//! Expected shape: labeling dominates when serialized; raising its slice
//! parallelism (1 → 4 → 10) shrinks the block walltime with diminishing
//! returns past the number of selected conformations.

use dflow::apps::rid::{self, RidConfig};
use dflow::bench_util::{artifacts_available, skip, Bench};
use dflow::engine::Engine;
use dflow::runtime::Runtime;

fn main() {
    if !artifacts_available() {
        skip("fig5: Rid-kit Block loop");
        return;
    }
    let rt = Runtime::global().unwrap();
    dflow::bench_util::warmup(&rt, &["lj_ef", "md_step", "nn_ef", "train_step"]);
    let engine = Engine::builder().runtime(rt).build();
    let mut b = Bench::new("fig5: Rid-kit Block (explore/select/label/train)");

    // ablation: labeling parallelism (the paper's default is 10)
    let mut t_prev = None;
    for label_par in [1usize, 4, 10] {
        let cfg = RidConfig {
            n_walkers: 4,
            md_calls: 3,
            n_train: 4,
            train_steps: 40,
            iterations: 1,
            label_parallelism: label_par,
            ..Default::default()
        };
        let (r, t) = b.case(&format!("block iteration, labeling parallelism={label_par}"), || {
            let r = engine.run(&rid::workflow(&cfg, 5)).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r
        });
        assert!(r.query_step("train-0-3").is_some(), "ensemble incomplete");
        if let Some(prev) = t_prev {
            b.metric(
                &format!("  speedup vs previous"),
                prev as f64 / t.as_secs_f64(),
                "x",
            );
        }
        t_prev = Some(t.as_secs_f64());
    }

    // two chained blocks: the loop carries dataset + models forward
    let cfg2 = RidConfig {
        n_walkers: 4,
        md_calls: 2,
        n_train: 4,
        train_steps: 30,
        iterations: 2,
        label_parallelism: 10,
        ..Default::default()
    };
    let (r, _) = b.case("2-iteration RiD loop", || {
        let r = engine.run(&rid::workflow(&cfg2, 6)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    // both blocks trained their ensembles
    for iter in 0..2 {
        for m in 0..4 {
            assert!(
                r.query_step(&format!("train-{iter}-{m}")).is_some(),
                "missing train-{iter}-{m}"
            );
        }
    }
    b.row("loop", "2 blocks complete, models updated each iteration");
}
