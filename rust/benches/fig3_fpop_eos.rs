//! F3 + C4 — Fig. 3 FPOP EOS flow, vs the paper's motivating baseline
//! ("simple scripted automation": a serial script with no engine).
//!
//! Expected shape: the dflow run parallelizes the FP tasks (bounded by
//! engine parallelism), the serial baseline pays the sum of task times;
//! restart-with-reuse costs ~nothing.

use dflow::apps::fpop;
use dflow::bench_util::{artifacts_available, skip, Bench};
use dflow::engine::Engine;
use dflow::runtime::{shapes, Runtime, Tensor};
use dflow::science::lj;

fn main() {
    if !artifacts_available() {
        skip("fig3: FPOP EOS flow");
        return;
    }
    let rt = Runtime::global().unwrap();
    dflow::bench_util::warmup(&rt, &["lj_ef"]);
    let mut b = Bench::new("fig3: FPOP EOS flow (prep -> N concurrent FP -> post)");

    let scales: Vec<f64> = (0..7).map(|i| 0.85 + 0.05 * i as f64).collect();
    let engine = Engine::builder().runtime(rt.clone()).build();
    let wf = fpop::eos_workflow(7, &scales, 2);

    // C4 baseline: the same science, as a flat serial script (no engine)
    let (_, serial) = b.case("baseline: serial script (no engine)", || {
        let x0 = lj::lattice(shapes::N_ATOMS, 1.2, 0.03, 7);
        // relax
        let mut x = Tensor::new(vec![shapes::N_ATOMS, 3], x0).unwrap();
        // same 200 descent steps the workflow's relax OP performs
        for _ in 0..200 {
            let out = rt.exec("lj_ef", &[x.clone()]).unwrap();
            for (xi, fi) in x.data.iter_mut().zip(&out[2].data) {
                *xi += (0.02 * fi).clamp(-0.1, 0.1);
            }
        }
        // fp tasks, strictly serial
        let mut energies = Vec::new();
        for s in &scales {
            let xs = Tensor::new(x.shape.clone(), lj::scale_config(&x.data, *s)).unwrap();
            let out = rt.exec("lj_ef", &[xs]).unwrap();
            energies.push(out[0].item() as f64);
        }
        let vols: Vec<f64> = scales.iter().map(|s| s * s * s).collect();
        dflow::science::eos::fit_eos(&vols, &energies).unwrap()
    });

    let (r, parallel) = b.case("dflow: engine-run EOS workflow", || {
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r
    });
    // NOTE: single-core testbed — compute-bound FP tasks cannot overlap on
    // wallclock, so the honest engine-level number is the orchestration
    // overhead vs the bare script (expect ~1x); scheduler-level concurrency
    // is demonstrated by the latency-bound C1-C3 benches.
    b.metric(
        "orchestration overhead (dflow / bare script)",
        parallel.as_secs_f64() / serial.as_secs_f64(),
        "x (expect ~1)",
    );
    b.metric("fit V0/Vref", r.outputs.params["v0"].as_float().unwrap(), "");
    b.metric("fit E0", r.outputs.params["e0"].as_float().unwrap(), "");
    b.metric("fit B0", r.outputs.params["b0"].as_float().unwrap(), "");

    // §2.5 restart
    let reuse = r.run.all_keyed();
    let (r2, warm) = b.case("dflow: resubmit with reuse_step", || {
        engine.run_with_reuse(&wf, reuse).unwrap()
    });
    b.metric("reused steps", r2.run.metrics.steps_reused.get() as f64, "");
    b.metric("restart speedup", parallel.as_secs_f64() / warm.as_secs_f64().max(1e-9), "x");
}
