//! Journal recovery battery: the kill-and-recover acceptance path (an
//! engine's in-memory state is dropped mid-workflow, a fresh engine
//! replays the journal and resubmits, and exactly the non-succeeded
//! suffix re-executes) plus a property suite that crashes the journal at a
//! random event boundary and tears the tail at a random byte.
//!
//! Run via `make test-journal` (part of `make ci`).

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use dflow::check;
use dflow::check::chaos::{ChaosAction, ChaosPlan};
use dflow::core::{
    ContainerTemplate, Dag, FnOp, OpError, ParamType, Signature, Step, Steps, Workflow,
};
use dflow::engine::{Backend, Engine, NodePhase, RunPhase};
use dflow::journal::{
    decode_segment, frame_record, segment_header, Journal, JournalEvent, Recorded, RunRegistry,
};
use dflow::storage::{CasStore, LocalStorage, MemStorage, StorageClient};

/// Per-task execution counter shared with the OP closure.
type Counts = Arc<Mutex<BTreeMap<String, u32>>>;

/// An n-task dataflow chain `t0 -> t1 -> ... -> t{n-1}`: task `ti`
/// receives `i` (t0 by constant, the rest from the predecessor's output),
/// counts its execution, and — while `gate` is set — fails fatally for
/// `i >= fail_from`, simulating the crash boundary.
fn chain_workflow(n: usize, counts: Counts, gate: Arc<AtomicBool>, fail_from: usize) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        move |ctx| {
            let i = ctx.get_int("i")?;
            *counts.lock().unwrap().entry(format!("t{i}")).or_insert(0) += 1;
            if gate.load(Ordering::SeqCst) && i as usize >= fail_from {
                return Err(OpError::Fatal("simulated crash boundary".into()));
            }
            ctx.set("o", i + 1);
            Ok(())
        },
    ));
    let mut dag = Dag::new("main");
    for i in 0..n {
        let mut s = Step::new(&format!("t{i}"), "op").key(&format!("t{i}"));
        if i == 0 {
            s = s.param("i", 0i64);
        } else {
            s = s.param_from_step("i", &format!("t{}", i - 1), "o");
        }
        dag = dag.task(s);
    }
    Workflow::new("chain")
        .container(ContainerTemplate::new("op", op))
        .dag(dag)
        .entrypoint("main")
}

fn counts_of(counts: &Counts) -> BTreeMap<String, u32> {
    counts.lock().unwrap().clone()
}

/// The acceptance test: k of n nodes succeed, the engine "process" dies
/// (every in-memory handle dropped), a fresh `Engine` + `Journal::open`
/// over the same directory resubmits the run, all n nodes report
/// Succeeded/Reused, execution counters confirm exactly n−k fresh
/// executions, and the registry serves the merged pre-/post-crash
/// timeline.
#[test]
fn kill_and_recover_end_to_end() {
    let dir = std::env::temp_dir().join(format!("dflow-journal-e2e-{}", dflow::util::next_id()));
    let storage: Arc<dyn StorageClient> = Arc::new(LocalStorage::new(&dir).unwrap());
    let counts: Counts = Arc::new(Mutex::new(BTreeMap::new()));
    let gate = Arc::new(AtomicBool::new(true));
    let (n, k) = (8usize, 5usize);
    let wf = chain_workflow(n, counts.clone(), gate.clone(), k);

    // "process" 1: the run dies after k successes; every handle drops
    let run_id = {
        let journal = Arc::new(Journal::open(storage.clone()).unwrap());
        let engine = Engine::builder().storage(storage.clone()).journal(journal).build();
        let r = engine.run(&wf).unwrap();
        assert!(!r.succeeded(), "the gate must fail the run mid-DAG");
        assert_eq!(r.run.count_phase(NodePhase::Succeeded), k);
        r.run.id
    };

    // "process" 2: fresh journal handle + fresh engine over the same store
    gate.store(false, Ordering::SeqCst);
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let rec = journal.replay(run_id).unwrap();
    assert_eq!(rec.phase, RunPhase::Failed);
    assert_eq!(rec.keyed.len(), k, "exactly the k journaled successes are reusable");
    assert_eq!(rec.count_phase(NodePhase::Succeeded), k);

    let before = counts_of(&counts);
    let engine = Engine::builder().storage(storage.clone()).journal(journal.clone()).build();
    let r2 = engine.resubmit(&wf, run_id).unwrap();
    assert!(r2.succeeded(), "{:?}", r2.error);
    assert_eq!(r2.run.id, run_id, "resubmission continues the journaled run id");
    assert_eq!(r2.run.metrics.steps_reused.get() as usize, k);
    assert_eq!(
        r2.run.count_phase(NodePhase::Succeeded) + r2.run.count_phase(NodePhase::Reused),
        n,
        "all n nodes must close successfully"
    );
    let after = counts_of(&counts);
    for i in 0..n {
        let key = format!("t{i}");
        let delta = after.get(&key).copied().unwrap_or(0) - before.get(&key).copied().unwrap_or(0);
        if i < k {
            assert_eq!(delta, 0, "journaled success {key} must not re-execute");
        } else {
            assert_eq!(delta, 1, "{key} must execute exactly once on resubmit");
        }
    }

    // the registry returns the merged pre- and post-crash event history
    let registry = RunRegistry::new(journal);
    let timeline = registry.node_timeline(run_id, None).unwrap();
    assert!(timeline.iter().any(|r| matches!(r.event, JournalEvent::RunSubmitted { .. })));
    assert!(timeline.iter().any(|r| matches!(r.event, JournalEvent::RunResubmitted { .. })));
    let succeeded = timeline
        .iter()
        .filter(|r| matches!(r.event, JournalEvent::NodeSucceeded { .. }))
        .count();
    assert_eq!(succeeded, n, "pre-crash and post-crash successes must both be present");
    // per-node merge: t0 succeeded before the crash AND was reused after
    let t0 = registry.node_timeline(run_id, Some("main/t0")).unwrap();
    assert!(t0.iter().any(|r| matches!(r.event, JournalEvent::NodeSucceeded { .. })));
    assert!(t0.iter().any(|r| matches!(r.event, JournalEvent::NodeReused { .. })));
    let run = registry.get_run(run_id).unwrap();
    assert_eq!(run.phase, RunPhase::Succeeded);
    assert_eq!(run.resubmissions, 1);
    let rows = registry.list_runs().unwrap();
    assert!(rows.iter().any(|s| s.run_id == run_id && s.phase == RunPhase::Succeeded));
    fs::remove_dir_all(dir).ok();
}

/// Property suite (ISSUE satellite): run an n-node DAG to completion,
/// crash the journal at a **random event boundary**, tear the tail at a
/// **random byte**, then assert a fresh engine's resubmit re-runs exactly
/// the non-succeeded suffix — zero re-execution of journaled successes —
/// and that re-replay is idempotent.
#[test]
fn crash_at_random_event_boundary_recovers_exactly_the_suffix() {
    check::forall_cases("journal crash recovery", 16, |rng| {
        let n = 4 + rng.below(5) as usize;
        let mem = Arc::new(MemStorage::new());
        let storage: Arc<dyn StorageClient> = mem.clone();
        let counts: Counts = Arc::new(Mutex::new(BTreeMap::new()));
        let gate = Arc::new(AtomicBool::new(false)); // never fails on its own
        let wf = chain_workflow(n, counts.clone(), gate, n + 1);

        let run_id = {
            let journal = Arc::new(
                Journal::open(storage.clone()).unwrap().segment_max_bytes(512),
            );
            let engine = Engine::builder().storage(storage.clone()).journal(journal).build();
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "{:?}", r.error);
            r.run.id
        };

        // flatten the journal into per-segment record payloads
        let prefix = format!("journal/run{run_id}/");
        let seg_keys = mem.list(&prefix).unwrap();
        let mut per_seg: Vec<(String, Vec<Vec<u8>>)> = Vec::new();
        let mut total = 0usize;
        for key in &seg_keys {
            let (payloads, torn) = decode_segment(&mem.download(key).unwrap()).unwrap();
            assert!(torn.is_none(), "a clean run must have no torn tail");
            total += payloads.len();
            per_seg.push((key.clone(), payloads));
        }

        // crash: keep a random prefix of events (≥ the submission record),
        // then tear the cut segment at a random byte of the next record
        let cut = 1 + rng.below(total as u64) as usize;
        let mut kept = 0usize;
        let mut expect_succeeded: BTreeSet<String> = BTreeSet::new();
        for (key, payloads) in &per_seg {
            if kept >= cut {
                mem.delete(key).unwrap();
                continue;
            }
            let take = payloads.len().min(cut - kept);
            for p in &payloads[..take] {
                if let JournalEvent::NodeSucceeded { key: Some(k), .. } =
                    Recorded::parse(p).unwrap().event
                {
                    expect_succeeded.insert(k);
                }
            }
            let mut rebuilt = segment_header();
            for p in &payloads[..take] {
                rebuilt.extend_from_slice(&frame_record(p));
            }
            if take < payloads.len() {
                // torn tail: a random-length prefix of the next frame
                // (length 0 = a crash exactly at the record boundary)
                let frame = frame_record(&payloads[take]);
                let torn_len = rng.below(frame.len() as u64) as usize;
                rebuilt.extend_from_slice(&frame[..torn_len]);
            }
            mem.upload(key, &rebuilt).unwrap();
            kept += take;
        }

        // a fresh "process" recovers and resubmits
        let journal = Arc::new(Journal::open(storage.clone()).unwrap().segment_max_bytes(512));
        let rec = journal.replay(run_id).unwrap();
        assert_eq!(
            rec.keyed.keys().cloned().collect::<BTreeSet<_>>(),
            expect_succeeded,
            "replay must recover exactly the journaled successes"
        );
        let before = counts_of(&counts);
        let engine = Engine::builder().storage(storage.clone()).journal(journal.clone()).build();
        let r2 = engine.resubmit(&wf, run_id).unwrap();
        assert!(r2.succeeded(), "{:?}", r2.error);
        let after = counts_of(&counts);
        let mut fresh = 0usize;
        for i in 0..n {
            let key = format!("t{i}");
            let delta =
                after.get(&key).copied().unwrap_or(0) - before.get(&key).copied().unwrap_or(0);
            if expect_succeeded.contains(&key) {
                assert_eq!(delta, 0, "journaled success {key} re-executed");
            } else {
                assert_eq!(delta, 1, "{key} must run exactly once on resubmit");
                fresh += 1;
            }
        }
        assert_eq!(fresh, n - expect_succeeded.len(), "exactly the suffix re-executes");
        assert_eq!(r2.run.metrics.steps_reused.get() as usize, expect_succeeded.len());

        // idempotent re-replay over the merged pre-/post-crash journal
        let a = journal.replay(run_id).unwrap();
        let b = journal.replay(run_id).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.phase, RunPhase::Succeeded);
        assert_eq!(a.keyed.len(), n, "every node is reusable after recovery");
    });
}

/// Chaos extension of the crash-boundary suite (ISSUE 7 satellite): the
/// first "process" runs under a seeded [`ChaosPlan`] that kills one of
/// its two backends at a random event boundary (an in-flight attempt
/// fails over, journaling `NodeFailedOver`); the crash then keeps a
/// random event prefix with synthetic eviction/failover records spliced
/// in at random positions. The recovery contract must be unchanged:
/// replay recovers exactly the journaled successes, resubmit re-runs
/// exactly the non-succeeded suffix, and the informational chaos events
/// never alter a recovered node phase.
#[test]
fn chaotic_crash_boundary_still_recovers_exactly_the_suffix() {
    check::forall_cases("journal chaos crash recovery", 16, |rng| {
        let n = 4 + rng.below(5) as usize;
        let mem = Arc::new(MemStorage::new());
        let storage: Arc<dyn StorageClient> = mem.clone();
        let counts: Counts = Arc::new(Mutex::new(BTreeMap::new()));
        let gate = Arc::new(AtomicBool::new(false)); // chaos, not the gate, is the hazard
        let wf = chain_workflow(n, counts.clone(), gate, n + 1);

        // "process" 1: two slot backends; b0 is killed at a random event
        // boundary — whatever is in flight there fails over to b1 and the
        // run still succeeds (failover retries are budget-free)
        let run_id = {
            let journal =
                Arc::new(Journal::open(storage.clone()).unwrap().segment_max_bytes(512));
            let engine = Engine::builder()
                .storage(storage.clone())
                .journal(journal)
                .backend(Backend::local_slots("b0", 2))
                .backend(Backend::local_slots("b1", 2))
                .build();
            let plan = ChaosPlan::new();
            let b0 = Arc::clone(engine.placer().unwrap().backend("b0").unwrap());
            plan.at(rng.below((3 * n) as u64), ChaosAction::KillBackend(b0));
            plan.install(&engine);
            let r = engine.run(&wf).unwrap();
            assert!(r.succeeded(), "chaotic run must fail over, not fail: {:?}", r.error);
            check::assert_all_drained(&engine, None, None);
            r.run.id
        };

        // flatten the journal, then splice synthetic informational chaos
        // records at random positions (never before the submission record)
        let prefix = format!("journal/run{run_id}/");
        let seg_keys = mem.list(&prefix).unwrap();
        let mut per_seg: Vec<(String, Vec<Vec<u8>>)> = Vec::new();
        let mut total = 0usize;
        for key in &seg_keys {
            let (payloads, torn) = decode_segment(&mem.download(key).unwrap()).unwrap();
            assert!(torn.is_none(), "a completed run must have no torn tail");
            total += payloads.len();
            per_seg.push((key.clone(), payloads));
        }
        let synthetic = [
            JournalEvent::NodeEvicted {
                path: "main/t0".into(),
                attempt: 0,
                by: "run 999".into(),
            },
            JournalEvent::NodeFailedOver {
                path: "main/t1".into(),
                backend: "b0".into(),
                attempt: 0,
                message: "backend 'b0' died while attempt 0 was in flight".into(),
            },
        ];
        for event in synthetic {
            let seg = rng.below(per_seg.len() as u64) as usize;
            let payloads = &mut per_seg[seg].1;
            let pos = 1 + rng.below(payloads.len() as u64) as usize;
            payloads.insert(pos, Recorded { at_ms: 0, event }.encode());
            total += 1;
        }

        // crash: keep a random prefix of events, tear the cut segment at a
        // random byte of the next record (same tear as the base suite)
        let cut = 1 + rng.below(total as u64) as usize;
        let mut kept = 0usize;
        let mut expect_succeeded: BTreeSet<String> = BTreeSet::new();
        for (key, payloads) in &per_seg {
            if kept >= cut {
                mem.delete(key).unwrap();
                continue;
            }
            let take = payloads.len().min(cut - kept);
            for p in &payloads[..take] {
                if let JournalEvent::NodeSucceeded { key: Some(k), .. } =
                    Recorded::parse(p).unwrap().event
                {
                    expect_succeeded.insert(k);
                }
            }
            let mut rebuilt = segment_header();
            for p in &payloads[..take] {
                rebuilt.extend_from_slice(&frame_record(p));
            }
            if take < payloads.len() {
                let frame = frame_record(&payloads[take]);
                let torn_len = rng.below(frame.len() as u64) as usize;
                rebuilt.extend_from_slice(&frame[..torn_len]);
            }
            mem.upload(key, &rebuilt).unwrap();
            kept += take;
        }

        // a fresh chaos-free "process" recovers and resubmits
        let journal = Arc::new(Journal::open(storage.clone()).unwrap().segment_max_bytes(512));
        let rec = journal.replay(run_id).unwrap();
        assert_eq!(
            rec.keyed.keys().cloned().collect::<BTreeSet<_>>(),
            expect_succeeded,
            "replay must recover exactly the journaled successes"
        );
        let before = counts_of(&counts);
        let engine =
            Engine::builder().storage(storage.clone()).journal(journal.clone()).build();
        let r2 = engine.resubmit(&wf, run_id).unwrap();
        assert!(r2.succeeded(), "{:?}", r2.error);
        let after = counts_of(&counts);
        for i in 0..n {
            let key = format!("t{i}");
            let delta =
                after.get(&key).copied().unwrap_or(0) - before.get(&key).copied().unwrap_or(0);
            if expect_succeeded.contains(&key) {
                assert_eq!(delta, 0, "journaled success {key} re-executed");
            } else {
                assert_eq!(delta, 1, "{key} must run exactly once on resubmit");
            }
        }
        assert_eq!(r2.run.metrics.steps_reused.get() as usize, expect_succeeded.len());

        // idempotent re-replay over the merged journal, chaos records and all
        let a = journal.replay(run_id).unwrap();
        let b = journal.replay(run_id).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.phase, RunPhase::Succeeded);
        check::assert_all_drained(&engine, None, Some(&journal));
    });
}

/// The journal speaks the plain `StorageClient` surface, so it works
/// unchanged over the CAS dedup layer — segments and artifacts share one
/// content-addressed store.
#[test]
fn journal_over_cas_storage_recovers_with_full_reuse() {
    let storage: Arc<dyn StorageClient> = Arc::new(CasStore::new(Arc::new(MemStorage::new())));
    let counts: Counts = Arc::new(Mutex::new(BTreeMap::new()));
    let gate = Arc::new(AtomicBool::new(false));
    let n = 4usize;
    let wf = chain_workflow(n, counts.clone(), gate, n + 1);
    let run_id = {
        let journal = Arc::new(Journal::open(storage.clone()).unwrap());
        let engine = Engine::builder().storage(storage.clone()).journal(journal).build();
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r.run.id
    };
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let before = counts_of(&counts);
    let engine = Engine::builder().storage(storage).journal(journal).build();
    let r2 = engine.resubmit(&wf, run_id).unwrap();
    assert!(r2.succeeded(), "{:?}", r2.error);
    assert_eq!(r2.run.metrics.steps_reused.get() as usize, n, "a finished run fully reuses");
    assert_eq!(counts_of(&counts), before, "no node may re-execute");
}

/// Satellite: a failed attempt's artifact namespace is reclaimed by the
/// engine (ROADMAP CAS follow-up) and the reclamation is journaled.
#[test]
fn failed_attempt_namespace_is_reclaimed_and_journaled() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let op = Arc::new(FnOp::new(Signature::new(), |ctx| {
        ctx.write_artifact("junk", b"partial output")?;
        Err(OpError::Fatal("boom after writing".into()))
    }));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("boom", op))
        .steps(Steps::new("main").then(Step::new("s", "boom")))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());
    let leftovers = mem.list(&format!("run{}/", r.run.id)).unwrap();
    assert!(leftovers.is_empty(), "failed attempt artifacts must be reclaimed: {leftovers:?}");
    assert_eq!(r.run.metrics.artifacts_reclaimed.get(), 1);
    let timeline = RunRegistry::new(journal).node_timeline(r.run.id, None).unwrap();
    assert!(
        timeline
            .iter()
            .any(|rec| matches!(rec.event, JournalEvent::ArtifactsReclaimed { objects: 1, .. })),
        "the reclamation itself must be journaled"
    );
}
