//! Service control-plane battery: multi-tenant concurrency over shared
//! backends (quotas, no over-commit), fair-share dispatch, live cancel
//! with exactly-once capacity release, retry-of-suffix, the adaptive
//! scheduler pool under a latency-bound HPC fan-out, and the batched
//! journal appender's upload budget, end-to-end.
//!
//! Run via `make test-service` (part of `make ci`).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use dflow::bench_util::ConcurrencyProbe;
use dflow::check;
use dflow::cluster::{Cluster, Resources};
use dflow::core::{
    ContainerTemplate, Dag, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::{Backend, Engine, RunPhase};
use dflow::hpc::{HpcScheduler, PartitionSpec};
use dflow::journal::{Appender, Journal, JournalEvent};
use dflow::service::{RunWatch, ServiceConfig, WorkflowService};
use dflow::storage::{CountingStorage, MemStorage, StorageClient, StorageError};

/// A 4-node run spanning all three backend kinds: three parallel pinned
/// tasks (k8s pod, HPC partition slot, local slot) and a join.
fn tri_backend_workflow(name: &str, work_ms: u64) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().out_param("v", ParamType::Int),
        move |ctx| {
            std::thread::sleep(Duration::from_millis(work_ms));
            ctx.set("v", 1i64);
            Ok(())
        },
    ));
    let join = Arc::new(FnOp::new(
        Signature::new()
            .in_param("a", ParamType::Int)
            .in_param("b", ParamType::Int)
            .in_param("c", ParamType::Int)
            .out_param("sum", ParamType::Int),
        |ctx| {
            let s = ctx.get_int("a")? + ctx.get_int("b")? + ctx.get_int("c")?;
            ctx.set("sum", s);
            Ok(())
        },
    ));
    Workflow::new(name)
        .container(ContainerTemplate::new("op", op).resources(Resources::cpu(500)))
        .container(ContainerTemplate::new("join", join))
        .dag(
            Dag::new("main")
                .task(Step::new("cloud", "op").on_backend("k8s"))
                .task(Step::new("hpc", "op").on_backend("hpc"))
                .task(Step::new("edge", "op").on_backend("edge"))
                .task(
                    Step::new("sum", "join")
                        .param_from_step("a", "cloud", "v")
                        .param_from_step("b", "hpc", "v")
                        .param_from_step("c", "edge", "v"),
                )
                .out_param_from("total", "sum", "sum"),
        )
        .entrypoint("main")
}

struct TriBackendRig {
    cluster: Arc<Cluster>,
    hpc: Arc<HpcScheduler>,
    engine: Arc<Engine>,
}

fn tri_backend_engine() -> TriBackendRig {
    let cluster = Arc::new(Cluster::uniform(4, Resources::cpu(2000), 0));
    let hpc = HpcScheduler::new(vec![PartitionSpec::new("batch", 4, Duration::from_secs(30))]);
    let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
    let engine = Arc::new(
        Engine::builder()
            .backend(Backend::cluster("k8s", Arc::clone(&cluster)))
            .backend(Backend::partition("hpc", Arc::clone(&hpc), "batch"))
            .backend(Backend::local_slots("edge", 4))
            .journal(journal)
            .build(),
    );
    TriBackendRig { cluster, hpc, engine }
}

/// The acceptance scenario: one service, ≥8 concurrent multi-node runs
/// from 3 tenants over 3 shared backends, quotas enforced, nothing
/// over-committed, every lease/pod returned.
#[test]
fn nine_concurrent_runs_from_three_tenants_share_three_backends() {
    let rig = tri_backend_engine();
    let config = ServiceConfig {
        max_live_runs: 9,
        default_tenant_quota: 3,
        ..ServiceConfig::default()
    };
    let svc = WorkflowService::start(Arc::clone(&rig.engine), config).unwrap();
    let tenants = ["alice", "bob", "carol"];
    let mut ids = Vec::new();
    for tenant in &tenants {
        for i in 0..3 {
            let wf = tri_backend_workflow(&format!("{tenant}-{i}"), 40);
            ids.push((tenant.to_string(), svc.submit(tenant, wf).unwrap()));
        }
    }
    assert!(svc.wait_idle(Duration::from_secs(60)), "service never drained");

    // every run closed Succeeded, under its own id
    for (_, id) in &ids {
        let rec = svc.registry().get_run(*id).unwrap();
        assert_eq!(rec.phase, RunPhase::Succeeded, "run {id}");
        assert_eq!(rec.nodes.len(), 4);
    }
    let rows = svc.registry().list_runs().unwrap();
    assert_eq!(rows.len(), 9);

    // per-tenant accounting and quota enforcement (live_peak is the
    // high-water mark of concurrently live runs per tenant)
    for tenant in &tenants {
        assert_eq!(svc.metrics().submitted.get(tenant), 3);
        assert_eq!(svc.metrics().started.get(tenant), 3);
        assert_eq!(svc.metrics().succeeded.get(tenant), 3);
        let peak = svc.metrics().live_peak.get(tenant);
        assert!(
            (1..=3).contains(&peak),
            "tenant {tenant} live peak {peak} violates quota 3"
        );
    }

    // shared backends: no over-commit, all capacity returned (pods, leases
    // and partition jobs via the shared audit)
    for s in rig.engine.backend_stats() {
        assert!(s.placed >= 9, "backend {} placed {}", s.name, s.placed);
    }
    let hpc_peak = rig.engine.placer().unwrap().backend("hpc").unwrap().peak_inflight();
    assert!(hpc_peak <= 4, "hpc over-committed: peak {hpc_peak} > 4 slots");
    let edge_peak = rig.engine.placer().unwrap().backend("edge").unwrap().peak_inflight();
    assert!(edge_peak <= 4, "edge over-committed: peak {edge_peak} > 4 slots");
    check::assert_all_drained(&rig.engine, None, None);
    let st = rig.hpc.partition_stats("batch").unwrap();
    assert_eq!(st.submitted, st.completed, "every HPC job must complete");
}

/// Fair-share: with one execution slot, a guest tenant's single run must
/// start right after the hog's first run — not behind its whole backlog.
#[test]
fn fair_share_keeps_a_flooding_tenant_from_starving_others() {
    let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
    let engine = Arc::new(
        Engine::builder().backend(Backend::local_slots("box", 1)).journal(journal).build(),
    );
    let config = ServiceConfig {
        max_live_runs: 1,
        default_tenant_quota: 1,
        ..ServiceConfig::default()
    };
    let svc = WorkflowService::start(engine, config).unwrap();
    let slow_wf = |name: &str| {
        let op = Arc::new(FnOp::new(Signature::new(), |_| {
            std::thread::sleep(Duration::from_millis(100));
            Ok(())
        }));
        Workflow::new(name)
            .container(ContainerTemplate::new("op", op))
            .steps(Steps::new("main").then(Step::new("s", "op")))
            .entrypoint("main")
    };
    let mut hog_ids = Vec::new();
    for i in 0..6 {
        hog_ids.push(svc.submit("hog", slow_wf(&format!("hog-{i}"))).unwrap());
    }
    // let the first hog run start, then the guest arrives
    std::thread::sleep(Duration::from_millis(30));
    let guest_id = svc.submit("guest", slow_wf("guest-0")).unwrap();
    assert!(svc.wait_idle(Duration::from_secs(60)), "service never drained");
    let order = svc.start_order();
    assert_eq!(order.len(), 7);
    let guest_pos = order.iter().position(|(_, id)| *id == guest_id).unwrap();
    assert!(
        guest_pos <= 1,
        "guest started at position {guest_pos}, starved behind the hog backlog: {order:?}"
    );
    assert_eq!(svc.metrics().succeeded.get("hog"), 6);
    assert_eq!(svc.metrics().succeeded.get("guest"), 1);
}

/// `cancel(run_id)` mid-flight: the run closes `Cancelled` (journaled),
/// in-flight OPs stop through their tokens, every pod/lease is released
/// exactly once, and a retry of the same id then completes by re-running
/// only what had not succeeded.
#[test]
fn cancel_stops_a_live_run_and_retry_resumes_the_suffix() {
    let cluster = Arc::new(Cluster::uniform(2, Resources::cpu(1000), 0));
    let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
    let engine = Arc::new(
        Engine::builder()
            .backend(Backend::cluster("k8s", Arc::clone(&cluster)))
            .backend(Backend::local_slots("edge", 2))
            .journal(journal)
            .build(),
    );
    let svc = WorkflowService::start(engine.clone(), ServiceConfig::default()).unwrap();

    // fast first step (succeeds pre-cancel, keyed for reuse), then a slow
    // cooperative fan-out split across both backends
    let executed: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let e2 = Arc::clone(&executed);
    let quick = Arc::new(FnOp::new(
        Signature::new().out_param("v", ParamType::Int),
        move |ctx| {
            e2.lock().unwrap().push("quick".to_string());
            ctx.set("v", 7i64);
            Ok(())
        },
    ));
    let slow = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            for _ in 0..500 {
                ctx.checkpoint()?; // cooperative: observes run cancel
                std::thread::sleep(Duration::from_millis(4));
            }
            ctx.set("y", ctx.get_int("x")?);
            Ok(())
        },
    ));
    let wf = Workflow::new("cancellable")
        .container(ContainerTemplate::new("quick", quick).resources(Resources::cpu(0)))
        .container(ContainerTemplate::new("slow", slow).resources(Resources::cpu(500)))
        .steps(
            Steps::new("main")
                .then(Step::new("head", "quick").key("head"))
                .then(
                    Step::new("fan", "slow")
                        .param("x", Value::ints(0..4))
                        .slices(Slices::over("x").stack("y").parallelism(4)),
                ),
        )
        .entrypoint("main");

    let id = svc.submit("alice", wf.clone()).unwrap();
    // wait until the fan-out is actually placed and running
    let mut saw_inflight = false;
    for _ in 0..500 {
        let total: usize = engine.backend_stats().iter().map(|s| s.inflight).sum();
        if total >= 2 {
            saw_inflight = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(4));
    }
    assert!(saw_inflight, "fan-out never started");
    svc.cancel(id, "operator changed plans").unwrap();
    // the live handle (if the run has not been reaped yet) closes Cancelled
    if let Some(run) = svc.run(id) {
        assert_eq!(run.wait_finished(), RunPhase::Cancelled);
    }
    assert!(svc.wait_idle(Duration::from_secs(30)));

    // journaled Cancelled + reason
    let rec = svc.registry().get_run(id).unwrap();
    assert_eq!(rec.phase, RunPhase::Cancelled);
    assert_eq!(rec.message, "operator changed plans");
    assert_eq!(svc.metrics().cancelled.get("alice"), 1);

    // capacity released exactly once: nothing in flight, bound == released
    let mut drained = false;
    for _ in 0..500 {
        let total: usize = engine.backend_stats().iter().map(|s| s.inflight).sum();
        if total == 0 && cluster.pods_in_flight() == 0 {
            drained = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(4));
    }
    assert!(drained, "cancel leaked a lease or pod");
    check::assert_all_drained(&engine, None, None);

    // retry the same id: the quick head is reused, only the fan re-runs
    let before = executed.lock().unwrap().len();
    let rid = svc.retry("alice", wf, id).unwrap();
    assert_eq!(rid, id, "retry must keep the journaled run id");
    assert!(svc.wait_idle(Duration::from_secs(120)), "retry never finished");
    let rec = svc.registry().get_run(id).unwrap();
    assert_eq!(rec.phase, RunPhase::Succeeded, "{}", rec.message);
    assert_eq!(rec.resubmissions, 1);
    assert_eq!(
        executed.lock().unwrap().len(),
        before,
        "the journaled quick step must be reused, not re-executed"
    );
}

/// ROADMAP "adaptive pool" regression: a 24-wide latency-bound HPC
/// fan-out on a parallelism-2 engine must not serialize into pool-sized
/// waves — workers blocked in the dispatcher's job wait stop counting
/// against the pool, replacements spawn (up to the hard cap), and the
/// fan-out runs at partition width.
#[test]
fn adaptive_pool_runs_latency_bound_hpc_fanout_at_partition_width() {
    let hpc = HpcScheduler::new(vec![PartitionSpec::new("batch", 24, Duration::from_secs(30))]);
    let engine = Arc::new(
        Engine::builder()
            .backend(Backend::partition("hpc", Arc::clone(&hpc), "batch"))
            .parallelism(2)
            .adaptive_cap(64)
            .build(),
    );
    let probe = ConcurrencyProbe::new();
    let p2 = Arc::clone(&probe);
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        move |ctx| {
            // runs on an HPC slot thread; the engine-side pool worker is
            // parked in the dispatcher wait meanwhile
            p2.with(|| std::thread::sleep(Duration::from_millis(60)));
            ctx.set("y", ctx.get_int("x")?);
            Ok(())
        },
    ));
    let wf = Workflow::new("hpc-fanout")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main").then(
                Step::new("fan", "op")
                    .param("x", Value::ints(0..24))
                    .slices(Slices::over("x").stack("y").parallelism(24)),
            ),
        )
        .entrypoint("main")
        .parallelism(24);
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert!(
        probe.peak() >= 8,
        "latency-bound fan-out serialized: peak HPC concurrency {} on a 24-slot \
         partition (static 2-worker pool symptom)",
        probe.peak()
    );
    let stats = engine.scheduler_stats();
    assert!(
        stats.peak_spawned > 2,
        "pool never grew past its size: {stats:?}"
    );
    assert!(stats.peak_spawned <= 64, "pool exceeded its hard cap: {stats:?}");
    check::assert_all_drained(&engine, None, None);
}

/// The batched appender's acceptance bound: journaling a ~100-event
/// fan-out through the background appender costs ≥5× fewer storage
/// uploads than the per-event synchronous path, with identical replayed
/// state.
#[test]
fn batched_appender_cuts_journal_uploads_at_least_5x_end_to_end() {
    fn fanout_wf(n: usize) -> Workflow {
        let op = Arc::new(FnOp::new(
            Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
            |ctx| {
                ctx.set("y", ctx.get_int("x")? * 2);
                Ok(())
            },
        ));
        Workflow::new("fanout")
            .container(ContainerTemplate::new("op", op))
            .steps(
                Steps::new("main").then(
                    Step::new("fan", "op")
                        .param("x", Value::ints(0..n as i64))
                        .slices(Slices::over("x").stack("y").parallelism(8)),
                ),
            )
            .entrypoint("main")
    }

    // synchronous journal: every event re-uploads the open segment
    let sync_counting = Arc::new(CountingStorage::new(Arc::new(MemStorage::new())));
    let sync_journal = Arc::new(
        Journal::open(Arc::clone(&sync_counting) as Arc<dyn StorageClient>).unwrap(),
    );
    let sync_engine = Engine::builder().journal(Arc::clone(&sync_journal)).build();
    let r1 = sync_engine.run(&fanout_wf(60)).unwrap();
    assert!(r1.succeeded(), "{:?}", r1.error);
    let sync_uploads = sync_counting.uploads.load(Ordering::Relaxed);
    let sync_events = sync_journal.replay(r1.run.id).unwrap().events;
    assert!(sync_events >= 100, "fan-out should journal ≥100 events, got {sync_events}");

    // batched appender: one segment upload per drained batch
    let batch_counting = Arc::new(CountingStorage::new(Arc::new(MemStorage::new())));
    let batch_journal = Arc::new(
        Journal::open(Arc::clone(&batch_counting) as Arc<dyn StorageClient>).unwrap(),
    );
    let appender = Appender::with_config(
        Arc::clone(&batch_journal),
        4096,
        Duration::from_millis(5),
    );
    let batch_engine =
        Engine::builder().journal_appender(Arc::clone(&appender)).build();
    let r2 = batch_engine.run(&fanout_wf(60)).unwrap();
    assert!(r2.succeeded(), "{:?}", r2.error);
    appender.flush();
    let batch_uploads = batch_counting.uploads.load(Ordering::Relaxed);
    assert_eq!(appender.errors(), 0);

    // identical recovered state, ≥5× fewer uploads
    let rec_sync = sync_journal.replay(r1.run.id).unwrap();
    let rec_batch = batch_journal.replay(r2.run.id).unwrap();
    assert_eq!(rec_sync.phase, RunPhase::Succeeded);
    assert_eq!(rec_batch.phase, RunPhase::Succeeded);
    assert_eq!(rec_sync.nodes.len(), rec_batch.nodes.len());
    assert!(
        batch_uploads * 5 <= sync_uploads,
        "batched appender must cut uploads ≥5×: batched {batch_uploads} vs sync {sync_uploads}"
    );
}

/// Admission-time static verification: a workflow whose backend selector no
/// registered backend can ever satisfy is rejected at
/// `WorkflowService::submit` with a `DF2xx` diagnostic that names the step
/// and the refusing selectors — the run never reaches the ready queue and
/// leaves no registry record. Lint *warnings* do not block: the run is
/// admitted, executes, and carries the warnings in its journal.
#[test]
fn unsatisfiable_selector_is_rejected_at_submit_and_never_queued() {
    let rig = tri_backend_engine();
    let svc =
        WorkflowService::start(Arc::clone(&rig.engine), ServiceConfig::default()).unwrap();

    let op = Arc::new(FnOp::new(Signature::new(), |_| Ok(())));
    let doomed = Workflow::new("doomed")
        .container(ContainerTemplate::new("op", Arc::clone(&op)).resources(Resources::cpu(500)))
        .dag(
            Dag::new("main")
                .task(Step::new("ok", "op").on_backend("edge"))
                .task(Step::new("nowhere", "op").on_backend("quantum-annealer")),
        )
        .entrypoint("main");
    let err = svc.submit("alice", doomed).unwrap_err();
    assert!(err.contains("DF2"), "must carry a placement code: {err}");
    assert!(err.contains("main/nowhere"), "must name the step: {err}");
    assert!(err.contains("quantum-annealer"), "must name the selector: {err}");
    assert!(
        err.contains("k8s") && err.contains("hpc") && err.contains("edge"),
        "must name the refusing backends: {err}"
    );
    assert_eq!(svc.metrics().rejected.get("alice"), 1);
    assert!(svc.start_order().is_empty(), "rejected run must never start");
    assert!(
        svc.registry().list_runs().unwrap().is_empty(),
        "rejected run must leave no journal record"
    );

    // warnings (here DF302: a 32-retry policy with zero backoff) admit and
    // run; the rendered warning lines are journaled and surface on both
    // the recovered state and the registry row
    let mut hot = dflow::core::StepPolicy::default();
    hot.retries = 32;
    let warned = Workflow::new("warned")
        .container(ContainerTemplate::new("op2", op).resources(Resources::cpu(500)))
        .steps(Steps::new("main").then(Step::new("s", "op2").policy(hot)))
        .entrypoint("main");
    let id = svc.submit("alice", warned).unwrap();
    assert!(svc.wait_idle(Duration::from_secs(20)));
    let rec = svc.registry().get_run(id).unwrap();
    assert_eq!(rec.phase, RunPhase::Succeeded);
    assert!(
        rec.lint.iter().any(|w| w.contains("DF302")),
        "journal must carry the lint warning: {:?}",
        rec.lint
    );
    let row = svc
        .registry()
        .list_runs()
        .unwrap()
        .into_iter()
        .find(|r| r.run_id == id)
        .unwrap();
    assert_eq!(row.lint_warnings, 1);
}

/// Storage decorator that, once armed, compacts a run's journal the
/// moment a raw segment download begins — deterministically reproducing
/// the race where a compaction lands between a live watch's segment
/// listing and its segment download.
struct CompactOnSegmentRead {
    inner: MemStorage,
    trap: Mutex<Option<(Arc<Journal>, u64)>>,
}

impl StorageClient for CompactOnSegmentRead {
    fn upload(&self, key: &str, data: &[u8]) -> Result<(), StorageError> {
        self.inner.upload(key, data)
    }

    fn download(&self, key: &str) -> Result<Vec<u8>, StorageError> {
        if key.contains("/seg-") {
            // take() first: compact() re-enters this client, and the
            // nested segment reads must pass through untrapped
            let armed = self.trap.lock().unwrap().take();
            if let Some((journal, run_id)) = armed {
                journal.compact(run_id).expect("closed run must compact");
            }
        }
        self.inner.download(key)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, StorageError> {
        self.inner.list(prefix)
    }

    fn copy(&self, src: &str, dst: &str) -> Result<(), StorageError> {
        self.inner.copy(src, dst)
    }

    fn delete(&self, key: &str) -> Result<(), StorageError> {
        self.inner.delete(key)
    }
}

/// Regression (PR 5 follow-up): a live `watch` whose run is compacted out
/// from under it mid-poll — after the raw-segment listing, before the
/// segment download — must resume from the compaction snapshot instead of
/// surfacing a vanished-segment error to the watcher.
#[test]
fn live_watch_straddling_a_compaction_resumes_from_the_snapshot() {
    let storage = Arc::new(CompactOnSegmentRead {
        inner: MemStorage::new(),
        trap: Mutex::new(None),
    });
    let journal =
        Arc::new(Journal::open(Arc::clone(&storage) as Arc<dyn StorageClient>).unwrap());
    let engine = Engine::builder().journal(Arc::clone(&journal)).build();

    let op = Arc::new(FnOp::new(
        Signature::new().out_param("v", ParamType::Int),
        |ctx| {
            ctx.set("v", 1i64);
            Ok(())
        },
    ));
    let wf = Workflow::new("watched")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(Step::new("s", "op")))
        .entrypoint("main");
    let done = engine.run(&wf).unwrap();
    assert!(done.succeeded(), "{:?}", done.error);
    let run_id = done.run.id;

    // a live tail delivers the raw stream through the close
    let mut watch = RunWatch::new(Arc::clone(&journal), run_id);
    let first = watch.poll().unwrap();
    assert!(
        first.iter().any(|r| matches!(r.event, JournalEvent::RunSucceeded)),
        "raw tail must deliver through the close"
    );

    // arm the trap: the watch's next poll re-reads the open segment, and
    // that download now compacts the run first — the segment vanishes
    // between the listing and the read
    *storage.trap.lock().unwrap() = Some((Arc::clone(&journal), run_id));
    let resumed = watch
        .poll()
        .expect("a watch straddling a compaction must resume, not error");
    assert!(
        storage.trap.lock().unwrap().is_none(),
        "trap never fired: the poll did not reach a segment download"
    );
    assert!(journal.has_snapshot(run_id).unwrap(), "compaction must have landed");
    assert!(
        matches!(resumed.first().map(|r| &r.event), Some(JournalEvent::Snapshot { .. })),
        "fallback must re-deliver the folded stream from its snapshot"
    );
    assert!(
        resumed.iter().any(|r| match &r.event {
            JournalEvent::Snapshot { run } => run.phase == RunPhase::Succeeded,
            _ => false,
        }),
        "the folded snapshot must still close the run"
    );

    // and the stream stays quiet afterwards: nothing is re-delivered twice
    assert!(watch.poll().unwrap().is_empty(), "fallback must not re-deliver");
}
