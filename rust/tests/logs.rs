//! Flight-recorder battery: attempt-level OP log capture end to end.
//!
//! Covers the PR's acceptance path (a 3-step run whose middle step fails
//! after logging — the logs must be readable post-hoc, after compaction,
//! and inline in the journaled failure), the durability edges (reclaimed
//! attempts, resubmit-after-crash, deliberate purge), the cross-process
//! tail (`dflow logs --follow`'s RunWatch pattern), the off-switch, and
//! the service-level per-tenant export.
//!
//! Run via `make test-logs` (part of `make ci`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use dflow::check;
use dflow::core::{
    ContainerTemplate, FnOp, OpError, ParamType, ShellOp, Signature, Step, StepPolicy, Steps,
    Workflow,
};
use dflow::engine::{Engine, NodePhase, RunPhase};
use dflow::journal::{Appender, Journal, JournalEvent, RunRegistry};
use dflow::obs::LogLevel;
use dflow::service::{RunWatch, ServiceConfig, WorkflowService};
use dflow::storage::{MemStorage, StorageClient};

/// Serial n-step chain `main/s1 .. main/sn`; every step logs two lines,
/// and step `fail_at` (1-based, if any) logs an ERROR then fails fatally.
fn logging_chain(n: usize, fail_at: Option<usize>) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int),
        move |ctx| {
            let i = ctx.get_int("i")?;
            ctx.log(LogLevel::Info, &format!("step {i}: preparing inputs"));
            ctx.log(LogLevel::Debug, &format!("step {i}: scratch dir ready"));
            if fail_at == Some(i as usize) {
                ctx.log(LogLevel::Error, "giving up: input checksum mismatch");
                return Err(OpError::Fatal("input checksum mismatch".into()));
            }
            Ok(())
        },
    ));
    let mut steps = Steps::new("main");
    for i in 1..=n {
        steps = steps.then(Step::new(&format!("s{i}"), "op").param("i", i as i64));
    }
    Workflow::new("logging-chain")
        .container(ContainerTemplate::new("op", op))
        .steps(steps)
        .entrypoint("main")
}

/// The acceptance scenario: 3 steps, step 2 fails after logging. The
/// captured lines must be readable through `RunRegistry::logs` (the
/// `dflow logs` backing) post-hoc, still readable after
/// `Journal::compact` (pointers are carried), show up inline in the
/// journaled `NodeFailed` message (`dflow get` forensics), and only a
/// deliberate `purge_logs` removes the chunks — leaving honest
/// error-marked entries behind.
#[test]
fn failing_step_logs_survive_failure_compaction_and_only_purge_removes_them() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let r = engine.run(&logging_chain(3, Some(2))).unwrap();
    assert!(!r.succeeded());
    assert!(r.error.as_deref().unwrap().contains("input checksum mismatch"));
    assert!(r.run.metrics.log_flushes.get() >= 2, "s1 and s2 both flushed");
    assert!(r.run.metrics.log_bytes.get() > 0);

    // post-hoc: the failing step's chunk decodes, in capture order
    let registry = RunRegistry::new(Arc::clone(&journal));
    let read_s2 = |reg: &RunRegistry| reg.logs(r.run.id, Some("main/s2"), None).unwrap();
    let chunks = read_s2(&registry);
    assert_eq!(chunks.len(), 1);
    let c = &chunks[0];
    assert_eq!((c.attempt, c.truncated, &c.error), (0, false, &None));
    assert!(c.key.starts_with(".logs/"), "log namespace must be dot-prefixed: {}", c.key);
    let msgs: Vec<&str> = c.lines.iter().map(|l| l.msg.as_str()).collect();
    assert_eq!(
        msgs,
        vec![
            "step 2: preparing inputs",
            "step 2: scratch dir ready",
            "giving up: input checksum mismatch"
        ]
    );
    assert!(c.lines.windows(2).all(|w| w[0].seq < w[1].seq), "seq must be monotonic");
    // the step that never ran has no logs — and saying so is an error,
    // not an empty success (typo protection)
    assert!(registry.logs(r.run.id, Some("main/s3"), None).is_err());

    // forensics: the journaled failure carries the last captured lines
    let rec = journal.replay(r.run.id).unwrap();
    let failed = &rec.nodes["main/s2"];
    assert_eq!(failed.phase, NodePhase::Failed);
    assert!(failed.message.contains("--- last"), "no log tail in: {}", failed.message);
    assert!(failed.message.contains("giving up: input checksum mismatch"));
    assert!(failed.message.contains("input checksum mismatch"), "original error kept");

    // compaction folds events into a snapshot but carries the pointers
    let report = journal.compact(r.run.id).unwrap();
    assert!(report.events_folded > 0);
    let registry = RunRegistry::new(Arc::clone(&journal));
    let after = read_s2(&registry);
    assert_eq!(after[0].lines, c.lines, "chunks must survive compaction");
    // ... and the folded failure message still shows the tail
    let rec = journal.replay(r.run.id).unwrap();
    assert!(rec.nodes["main/s2"].message.contains("giving up: input checksum mismatch"));

    // retention is deliberate: purge removes chunks, pointers stay behind
    // as evidence with an explicit read error
    let purged = journal.purge_logs(r.run.id).unwrap();
    assert!(purged >= 2, "s1 + s2 chunks should be purged, got {purged}");
    let gone = read_s2(&registry);
    assert_eq!(gone.len(), 1);
    assert!(gone[0].error.is_some(), "purged chunk must be marked unreadable");
    assert!(gone[0].lines.is_empty());

    check::assert_all_drained(&engine, None, Some(&journal));
}

/// A failed attempt's artifact namespace is reclaimed, but its log chunk
/// lives in the disjoint `.logs/` namespace and must survive.
#[test]
fn reclaimed_attempt_keeps_its_logs() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let op = Arc::new(FnOp::new(Signature::new(), |ctx| {
        ctx.log(LogLevel::Info, "writing partial output");
        ctx.write_artifact("junk", b"partial output")?;
        Err(OpError::Fatal("boom after writing".into()))
    }));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("boom", op))
        .steps(Steps::new("main").then(Step::new("s", "boom")))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());
    let leftovers = mem.list(&format!("run{}/", r.run.id)).unwrap();
    assert!(leftovers.is_empty(), "attempt artifacts must be reclaimed: {leftovers:?}");
    let log_keys = mem.list(&format!(".logs/run{}/", r.run.id)).unwrap();
    assert_eq!(log_keys.len(), 1, "the log chunk must NOT be reclaimed");
    let logs = RunRegistry::new(journal).logs(r.run.id, Some("main/s"), None).unwrap();
    assert_eq!(logs[0].lines[0].msg, "writing partial output");
}

/// Kill-and-recover: the pre-crash failing attempt's logs remain readable
/// after a fresh process resubmits the run to success — the flight
/// recorder is part of the durable history, not of engine memory.
#[test]
fn resubmit_after_crash_preserves_pre_crash_logs() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let gate = Arc::new(AtomicBool::new(true));
    let g = Arc::clone(&gate);
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int),
        move |ctx| {
            let i = ctx.get_int("i")?;
            ctx.log(LogLevel::Info, &format!("step {i}: running"));
            if g.load(Ordering::SeqCst) && i == 2 {
                ctx.log(LogLevel::Error, "pre-crash: power failure imminent");
                return Err(OpError::Fatal("simulated crash".into()));
            }
            Ok(())
        },
    ));
    let wf = Workflow::new("crashy")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(Step::new("s1", "op").param("i", 1i64).key("s1"))
                .then(Step::new("s2", "op").param("i", 2i64).key("s2"))
                .then(Step::new("s3", "op").param("i", 3i64).key("s3")),
        )
        .entrypoint("main");

    // "process" 1 dies after s2 fails
    let run_id = {
        let journal = Arc::new(Journal::open(storage.clone()).unwrap());
        let engine = Engine::builder().storage(storage.clone()).journal(journal).build();
        let r = engine.run(&wf).unwrap();
        assert!(!r.succeeded());
        r.run.id
    };

    // "process" 2 recovers and finishes
    gate.store(false, Ordering::SeqCst);
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let r2 = engine.resubmit(&wf, run_id).unwrap();
    assert!(r2.succeeded(), "{:?}", r2.error);

    let registry = RunRegistry::new(Arc::clone(&journal));
    let all = registry.logs(run_id, Some("main/s2"), None).unwrap();
    // attempt 0 (pre-crash, failed) and attempt 0 of the resubmission both
    // flushed under the same path; the pre-crash ERROR line must be there
    let flat: Vec<&str> =
        all.iter().flat_map(|c| c.lines.iter().map(|l| l.msg.as_str())).collect();
    assert!(
        flat.contains(&"pre-crash: power failure imminent"),
        "pre-crash logs lost: {flat:?}"
    );
    let rec = journal.replay(run_id).unwrap();
    assert_eq!(rec.phase, RunPhase::Succeeded);
    assert_eq!(rec.resubmissions, 1);
    check::assert_all_drained(&engine, None, Some(&journal));
}

/// The `dflow logs --follow` pattern: a second "process" opens the same
/// store, tails the journal with `RunWatch`, and materializes every
/// `NodeLogs` pointer it sees by downloading the chunk — ending with
/// exactly the lines the registry serves post-hoc.
#[test]
fn run_watch_tails_log_pointers_cross_process() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let run_id = {
        let journal = Arc::new(Journal::open(storage.clone()).unwrap());
        let engine = Engine::builder().storage(storage.clone()).journal(journal).build();
        let r = engine.run(&logging_chain(3, None)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r.run.id
    };

    // fresh handles = a different process sharing the store
    let journal = Arc::new(Journal::open(storage).unwrap());
    let store = Arc::clone(journal.storage());
    let tailed: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&tailed);
    let phase = RunWatch::new(Arc::clone(&journal), run_id)
        .follow(Duration::from_millis(10), move |rec| {
            if let JournalEvent::NodeLogs { key, .. } = &rec.event {
                let bytes = store.download(key).unwrap();
                for l in dflow::obs::logs::decode(&bytes) {
                    sink.lock().unwrap().push(l.msg);
                }
            }
        })
        .unwrap();
    assert_eq!(phase, RunPhase::Succeeded);

    let expected: Vec<String> = RunRegistry::new(journal)
        .logs(run_id, None, None)
        .unwrap()
        .iter()
        .flat_map(|c| c.lines.iter().map(|l| l.msg.clone()))
        .collect();
    assert_eq!(*tailed.lock().unwrap(), expected);
    assert_eq!(expected.len(), 6, "3 steps x 2 lines each");
}

/// The off-switch: with `log_capture(false)` every `ctx.log` is a no-op —
/// no `NodeLogs` records, no `.logs/` objects, no metrics, and the run is
/// otherwise unchanged.
#[test]
fn capture_off_is_fully_silent() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder()
        .storage(storage)
        .journal(journal.clone())
        .log_capture(false)
        .build();
    let r = engine.run(&logging_chain(2, None)).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.run.metrics.log_flushes.get(), 0);
    assert_eq!(r.run.metrics.log_bytes.get(), 0);
    assert!(mem.list(".logs/").unwrap().is_empty());
    let registry = RunRegistry::new(journal);
    assert!(registry.logs(r.run.id, None, None).unwrap().is_empty());
    let timeline = registry.node_timeline(r.run.id, None).unwrap();
    assert!(!timeline.iter().any(|rec| matches!(rec.event, JournalEvent::NodeLogs { .. })));
}

/// A timed-out attempt keeps what it said: the flush happens after the
/// deadline fires, and the journaled `NodeCancelled` reason carries the
/// captured tail.
#[test]
fn timed_out_attempt_flushes_logs_and_reason_carries_tail() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let op = Arc::new(FnOp::new(Signature::new(), |ctx| {
        ctx.log(LogLevel::Info, "halfway through the long computation");
        for _ in 0..200 {
            ctx.checkpoint()?;
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    }));
    let mut policy = StepPolicy::default();
    policy.timeout = Some(Duration::from_millis(40));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("slow", op))
        .steps(Steps::new("main").then(Step::new("s", "slow").policy(policy)))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());
    assert!(r.error.as_deref().unwrap().contains("timed out"));

    let registry = RunRegistry::new(Arc::clone(&journal));
    let logs = registry.logs(r.run.id, Some("main/s"), None).unwrap();
    assert_eq!(logs[0].lines[0].msg, "halfway through the long computation");
    let timeline = registry.node_timeline(r.run.id, None).unwrap();
    let reason = timeline
        .iter()
        .find_map(|rec| match &rec.event {
            JournalEvent::NodeCancelled { reason, .. } => Some(reason.clone()),
            _ => None,
        })
        .expect("a NodeCancelled record");
    assert!(reason.contains("timed out"));
    assert!(
        reason.contains("halfway through the long computation"),
        "no tail in cancel reason: {reason}"
    );
}

/// A panicking OP's payload is recorded as the attempt's last log line
/// and surfaces in the failure message.
#[test]
fn panic_payload_lands_in_logs_and_failure_message() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let op = Arc::new(FnOp::new(Signature::new(), |ctx| {
        ctx.log(LogLevel::Info, "loading shard 7");
        panic!("index out of range in shard 7");
    }));
    // panics are caught on the timed attempt path
    let mut policy = StepPolicy::default();
    policy.timeout = Some(Duration::from_secs(30));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("p", op))
        .steps(Steps::new("main").then(Step::new("s", "p").policy(policy)))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());
    let err = r.error.as_deref().unwrap();
    assert!(err.contains("panicked"), "{err}");
    assert!(err.contains("index out of range in shard 7"), "{err}");

    let logs = RunRegistry::new(journal).logs(r.run.id, Some("main/s"), None).unwrap();
    let msgs: Vec<&str> = logs[0].lines.iter().map(|l| l.msg.as_str()).collect();
    assert_eq!(msgs, vec!["loading shard 7", "OP panicked: index out of range in shard 7"]);
}

/// Script OPs get capture for free: stdout lines land as INFO, stderr as
/// WARN, and a failing script's output explains the failure.
#[test]
fn shell_op_streams_are_captured() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let op = ShellOp::new(
        Signature::new(),
        r#"
echo "scanning 42 input files"
echo "warning: checksum file missing" >&2
exit 3
"#,
    );
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("sh", Arc::new(op)))
        .steps(Steps::new("main").then(Step::new("s", "sh")))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());

    let logs = RunRegistry::new(Arc::clone(&journal)).logs(r.run.id, Some("main/s"), None).unwrap();
    let lines = &logs[0].lines;
    let info: Vec<&str> = lines
        .iter()
        .filter(|l| l.level == LogLevel::Info)
        .map(|l| l.msg.as_str())
        .collect();
    let warn: Vec<&str> = lines
        .iter()
        .filter(|l| l.level == LogLevel::Warn)
        .map(|l| l.msg.as_str())
        .collect();
    assert!(info.contains(&"scanning 42 input files"), "{info:?}");
    assert!(warn.contains(&"warning: checksum file missing"), "{warn:?}");
    // the forensic tail in the journaled failure includes the stderr line
    let rec = journal.replay(r.run.id).unwrap();
    assert!(rec.nodes["main/s"].message.contains("warning: checksum file missing"));
}

/// Ring-buffer truncation end to end: a chatty OP overflows its byte cap,
/// the flushed stream leads with the explicit truncation marker, keeps
/// the newest lines, and both the pointer and the registry agree.
#[test]
fn overflowing_buffer_truncates_oldest_with_explicit_marker() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let mut config = dflow::engine::EngineConfig::default();
    config.log_buffer_bytes = 512; // tiny ring
    let engine = Engine::builder()
        .storage(storage)
        .journal(journal.clone())
        .config(config)
        .build();
    let op = Arc::new(FnOp::new(Signature::new(), |ctx| {
        for i in 0..200 {
            ctx.log(LogLevel::Info, &format!("progress line {i} with some padding text"));
        }
        Ok(())
    }));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("chatty", op))
        .steps(Steps::new("main").then(Step::new("s", "chatty")))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);

    let logs = RunRegistry::new(journal).logs(r.run.id, Some("main/s"), None).unwrap();
    let c = &logs[0];
    assert!(c.truncated, "512-byte ring must overflow under 200 lines");
    assert_eq!(c.lines[0].seq, 0, "stream must lead with the marker line");
    assert!(c.lines[0].msg.contains("truncated"), "{}", c.lines[0].msg);
    assert_eq!(
        c.lines.last().unwrap().msg,
        "progress line 199 with some padding text",
        "the newest line always survives"
    );
}

/// Service layer: per-tenant log byte/flush counters are folded at reap
/// and exported under `dflow_svc_*` with tenant labels.
#[test]
fn service_exports_per_tenant_log_counters() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Arc::new(
        Engine::builder()
            .storage(storage)
            .journal_appender(Appender::spawn(Arc::clone(&journal)))
            .build(),
    );
    let svc = WorkflowService::start(engine, ServiceConfig::default()).unwrap();
    svc.submit("acme", logging_chain(2, None)).unwrap();
    assert!(svc.wait_idle(Duration::from_secs(30)), "service never drained");
    assert!(svc.metrics().log_flushes.get("acme") >= 2, "reap must fold log counters");
    assert!(svc.metrics().log_bytes.get("acme") > 0);
    let text = svc.export_metrics().to_prometheus();
    assert!(text.contains("dflow_svc_log_bytes_total"), "missing family:\n{text}");
    assert!(text.contains("dflow_svc_log_flushes_total"));
    assert!(text.contains(r#"tenant="acme""#));
}

/// Every `NodeLogs` pointer names a distinct `.logs/` object keyed by
/// run, node path, and attempt — two runs never collide.
#[test]
fn log_keys_are_namespaced_per_run_and_attempt() {
    let mem = Arc::new(MemStorage::new());
    let storage: Arc<dyn StorageClient> = mem.clone();
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder().storage(storage).journal(journal.clone()).build();
    let a = engine.run(&logging_chain(2, None)).unwrap();
    let b = engine.run(&logging_chain(2, None)).unwrap();
    assert!(a.succeeded() && b.succeeded());
    let registry = RunRegistry::new(journal);
    let mut keys: BTreeMap<String, u64> = BTreeMap::new();
    for id in [a.run.id, b.run.id] {
        for c in registry.logs(id, None, None).unwrap() {
            assert!(c.key.starts_with(&format!(".logs/run{id}/")), "{}", c.key);
            *keys.entry(c.key).or_insert(0) += 1;
        }
    }
    assert_eq!(keys.len(), 4, "2 runs x 2 steps");
    assert!(keys.values().all(|&n| n == 1), "keys must be unique: {keys:?}");
}
