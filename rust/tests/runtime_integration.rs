//! Integration: the PJRT runtime against real AOT artifacts.
//!
//! Skips (with a message) when `artifacts/` has not been built — run
//! `make artifacts` first. The key assertions: every artifact loads,
//! compiles and executes; and the compiled LJ kernel agrees with the
//! pure-rust reference to f32 tolerance, which transitively validates the
//! Pallas kernel (python tests assert kernel == jnp oracle; here we assert
//! artifact == rust reference).

use dflow::runtime::{shapes, Runtime, Tensor};
use dflow::science::lj;

macro_rules! runtime_or_skip {
    () => {
        match Runtime::global() {
            Some(rt) => rt,
            None => {
                eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn config(seed: u64) -> Tensor {
    Tensor::new(vec![shapes::N_ATOMS, 3], lj::lattice(shapes::N_ATOMS, 1.2, 0.05, seed)).unwrap()
}

#[test]
fn all_artifacts_compile_and_execute() {
    let rt = runtime_or_skip!();
    let names = rt.available();
    for required in
        ["lj_ef", "md_step", "descriptor", "nn_ef", "train_step", "eos_batch", "dock_score"]
    {
        assert!(names.iter().any(|n| n == required), "missing artifact {required}");
    }
}

#[test]
fn lj_ef_matches_rust_reference() {
    let rt = runtime_or_skip!();
    let x = config(3);
    let out = rt.exec("lj_ef", &[x.clone()]).unwrap();
    assert_eq!(out.len(), 3);
    let (e_ref, f_ref) = lj::lj_energy_forces(&x.data);
    let e_total_ref: f64 = e_ref.iter().map(|v| *v as f64).sum();
    assert!(
        (out[0].item() as f64 - e_total_ref).abs() < 1e-2 * (1.0 + e_total_ref.abs()),
        "artifact {} vs rust {}",
        out[0].item(),
        e_total_ref
    );
    // per-atom forces agree
    for (a, b) in out[2].data.iter().zip(&f_ref) {
        assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn md_step_conserves_and_moves() {
    let rt = runtime_or_skip!();
    let x = config(5);
    let v = Tensor::zeros(vec![shapes::N_ATOMS, 3]);
    let out = rt.exec("md_step", &[x.clone(), v]).unwrap();
    assert_eq!(out.len(), 4);
    let (x2, pe, ke) = (&out[0], out[2].item(), out[3].item());
    assert_ne!(x2.data, x.data, "MD did not move");
    assert!(pe < 0.0, "bound cluster should have negative PE, got {pe}");
    assert!(ke >= 0.0);
    // energy roughly conserved from the cold start: KE gained ≈ PE lost
    let pe0 = lj::lj_total_energy(&x.data);
    assert!(
        ((pe as f64 + ke as f64) - pe0).abs() < 0.05 * pe0.abs() + 1.0,
        "E drift: {} + {} vs {}",
        pe,
        ke,
        pe0
    );
}

#[test]
fn descriptor_shape_and_positivity() {
    let rt = runtime_or_skip!();
    let out = rt.exec("descriptor", &[config(7)]).unwrap();
    assert_eq!(out[0].shape, vec![shapes::N_ATOMS, shapes::N_DESC]);
    assert!(out[0].data.iter().all(|v| *v >= 0.0));
}

#[test]
fn nn_ef_runs_for_all_ensemble_members() {
    let rt = runtime_or_skip!();
    let x = config(11);
    let mut energies = Vec::new();
    for m in 0..shapes::ENSEMBLE {
        let theta = Tensor::new(vec![shapes::PARAM_DIM], rt.initial_params(m).to_vec()).unwrap();
        let out = rt.exec("nn_ef", &[theta, x.clone()]).unwrap();
        assert_eq!(out[1].shape, vec![shapes::N_ATOMS, 3]);
        assert!(out.iter().all(|t| t.data.iter().all(|v| v.is_finite())));
        energies.push(out[0].item());
    }
    // ensemble members must disagree (different seeds)
    let spread = energies.iter().cloned().fold(f32::MIN, f32::max)
        - energies.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 1e-3, "ensemble members identical: {energies:?}");
}

#[test]
fn train_step_decreases_loss() {
    let rt = runtime_or_skip!();
    // build a small batch labeled by the lj_ef artifact itself
    let mut xs = Vec::new();
    let mut es = Vec::new();
    let mut fs = Vec::new();
    for i in 0..shapes::BATCH {
        let x = config(100 + i as u64);
        let out = rt.exec("lj_ef", &[x.clone()]).unwrap();
        xs.extend_from_slice(&x.data);
        es.push(out[0].item());
        fs.extend_from_slice(&out[2].data);
    }
    let xs = Tensor::new(vec![shapes::BATCH, shapes::N_ATOMS, 3], xs).unwrap();
    let es = Tensor::new(vec![shapes::BATCH], es).unwrap();
    let fs = Tensor::new(vec![shapes::BATCH, shapes::N_ATOMS, 3], fs).unwrap();

    let mut theta = Tensor::new(vec![shapes::PARAM_DIM], rt.initial_params(0).to_vec()).unwrap();
    let mut m = Tensor::zeros(vec![shapes::PARAM_DIM]);
    let mut v = Tensor::zeros(vec![shapes::PARAM_DIM]);
    let mut t = Tensor::scalar(0.0);
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..40 {
        let out = rt
            .exec("train_step", &[theta, m, v, t, xs.clone(), es.clone(), fs.clone()])
            .unwrap();
        let mut it = out.into_iter();
        theta = it.next().unwrap();
        m = it.next().unwrap();
        v = it.next().unwrap();
        t = it.next().unwrap();
        last = it.next().unwrap().item();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first * 0.9, "loss did not decrease: {first} -> {last}");
    assert_eq!(t.item(), 40.0);
}

#[test]
fn eos_batch_has_interior_minimum() {
    let rt = runtime_or_skip!();
    let base = lj::lattice(shapes::N_ATOMS, 1.2, 0.0, 0);
    let k = shapes::EOS_POINTS;
    let mut stacked = Vec::new();
    for i in 0..k {
        let s = 0.85 + 0.3 * i as f64 / (k - 1) as f64;
        stacked.extend(lj::scale_config(&base, s));
    }
    let xs = Tensor::new(vec![k, shapes::N_ATOMS, 3], stacked).unwrap();
    let out = rt.exec("eos_batch", &[xs]).unwrap();
    let es = &out[0].data;
    let argmin = es
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert!(argmin > 0 && argmin < k - 1, "minimum at edge: {es:?}");
}

#[test]
fn dock_score_deterministic_with_spread() {
    let rt = runtime_or_skip!();
    let mut rng = dflow::util::Rng::new(9);
    let feats: Vec<f32> = (0..shapes::DOCK_BATCH * shapes::DOCK_FEATS)
        .map(|_| rng.normal() as f32)
        .collect();
    let t = Tensor::new(vec![shapes::DOCK_BATCH, shapes::DOCK_FEATS], feats).unwrap();
    let a = rt.exec("dock_score", &[t.clone()]).unwrap();
    let b = rt.exec("dock_score", &[t]).unwrap();
    assert_eq!(a[0].data, b[0].data);
    let mean = a[0].data.iter().sum::<f32>() / a[0].data.len() as f32;
    let var = a[0].data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
        / a[0].data.len() as f32;
    assert!(var.sqrt() > 0.1, "no spread in docking scores");
}

#[test]
fn runtime_is_thread_safe() {
    let rt = runtime_or_skip!();
    let mut handles = Vec::new();
    for i in 0..8 {
        let rt = rt.clone();
        handles.push(std::thread::spawn(move || {
            let x = config(i);
            let out = rt.exec("lj_ef", &[x]).unwrap();
            out[0].item()
        }));
    }
    for h in handles {
        let e = h.join().unwrap();
        assert!(e.is_finite() && e < 0.0);
    }
}

#[test]
fn compile_cache_amortizes() {
    let rt = runtime_or_skip!();
    let x = config(1);
    // warm
    rt.exec("lj_ef", &[x.clone()]).unwrap();
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        rt.exec("lj_ef", &[x.clone()]).unwrap();
    }
    let warm = t0.elapsed() / 5;
    // a warm execution must be far below any plausible compile time
    assert!(warm.as_millis() < 500, "warm exec too slow: {warm:?}");
}
