//! Regression + stress tests for the bounded step scheduler:
//! * a 2000-node diamond-chain DAG completes correctly on `parallelism(8)`
//!   with peak live workers ≤ 8 (no thread-per-node explosion);
//! * `depends_on` naming an unknown task is a hard error carrying the name;
//! * a step timeout cancels the attempt and cluster pod accounting returns
//!   to zero (no orphan thread keeps a pod bound);
//! * a 2000-node DAG split across 3 placement backends (k8s-sim + HPC
//!   partition + slot-capped local) keeps every backend's in-flight peak
//!   within that backend's capacity;
//! * 10k timed attempts share the engine's single timer-wheel thread (OS
//!   threads stay O(pool size), every deadline settles exactly once);
//! * a 10k-node DAG smoke (the 100k closer lives in the c1 bench) shows
//!   successor wakeups coalescing into per-completion batches.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dflow::bench_util::diamond_chain_workflow;
use dflow::check;
use dflow::cluster::{Cluster, Resources};
use dflow::core::{
    ContainerTemplate, Dag, FnOp, OpError, ParamType, Signature, Step, StepPolicy, Steps, Value,
    Workflow,
};
use dflow::engine::{Engine, NodePhase};

#[test]
fn two_thousand_node_dag_runs_on_eight_workers() {
    let (wf, probe, nodes) = diamond_chain_workflow(2002, 8);
    assert!(nodes >= 2000, "builder produced only {nodes} nodes");
    let engine = Engine::builder().parallelism(8).build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    // chain of diamonds, every op is +1: r = 1 + 2 * diamonds
    let expect = 1 + 2 * ((nodes - 1) / 3) as i64;
    assert_eq!(r.outputs.params["r"], Value::Int(expect));
    assert_eq!(r.run.count_phase(NodePhase::Succeeded), nodes);
    assert!(
        probe.peak() <= 8,
        "peak live workers {} exceeded parallelism 8",
        probe.peak()
    );
}

#[test]
fn deep_linear_chain_dag_completes() {
    // 500 strictly serial tasks: exercises ready-queue propagation depth
    // (each completion readies exactly one dependent)
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            ctx.set("y", ctx.get_int("x")? + 1);
            Ok(())
        },
    ));
    let mut dag = Dag::new("main").task(Step::new("n0", "op").param("x", 0i64));
    for i in 1..500 {
        dag = dag.task(
            Step::new(&format!("n{i}"), "op").param_from_step("x", &format!("n{}", i - 1), "y"),
        );
    }
    let dag = dag.out_param_from("r", "n499", "y");
    let wf = Workflow::new("chain")
        .container(ContainerTemplate::new("op", op))
        .dag(dag)
        .entrypoint("main");
    let r = Engine::builder().parallelism(4).build().run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.outputs.params["r"], Value::Int(500));
}

#[test]
fn unknown_dag_dependency_is_hard_error_with_task_name() {
    let op = Arc::new(FnOp::new(Signature::new(), |_| Ok(())));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("op", op))
        .dag(Dag::new("main").task(Step::new("a", "op").depends_on("does-not-exist")))
        .entrypoint("main");
    let err = Engine::local()
        .run(&wf)
        .err()
        .expect("validation should reject the unknown dependency");
    assert!(
        err.contains("does-not-exist"),
        "error must name the missing dependency: {err}"
    );
}

#[test]
fn timeout_cancels_op_and_pod_accounting_returns_to_zero() {
    let cluster = Arc::new(Cluster::uniform(1, Resources::cpu(1000), 0));
    let observed_cancel = Arc::new(AtomicBool::new(false));
    let ran_to_completion = Arc::new(AtomicBool::new(false));
    let (oc, rc) = (observed_cancel.clone(), ran_to_completion.clone());
    // a cooperative OP: checks its cancel token between work quanta
    let op = Arc::new(FnOp::new(
        Signature::new().out_param("ok", ParamType::Bool),
        move |ctx| {
            for _ in 0..400 {
                if ctx.cancel.is_cancelled() {
                    oc.store(true, Ordering::SeqCst);
                    return Err(OpError::Fatal("cancelled".into()));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            rc.store(true, Ordering::SeqCst);
            ctx.set("ok", true);
            Ok(())
        },
    ));
    let mut policy = StepPolicy::default();
    policy.timeout = Some(Duration::from_millis(40));
    let wf = Workflow::new("timeout")
        .container(ContainerTemplate::new("slow", op).resources(Resources::cpu(1000)))
        .steps(Steps::new("main").then(Step::new("s", "slow").policy(policy)))
        .entrypoint("main");
    let engine = Engine::builder().cluster(cluster.clone()).build();
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());
    assert!(r.error.unwrap().contains("timed out"));
    assert_eq!(r.run.metrics.timeouts.get(), 1);

    // the cancelled attempt must stop and hand its pod back: accounting
    // returns to zero shortly after the cancel token fires
    let mut drained = false;
    for _ in 0..400 {
        if cluster.pods_in_flight() == 0 {
            drained = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(drained, "pod still bound after timeout — orphan attempt leaked it");
    let (bound, released, _) = cluster.stats();
    assert_eq!(bound, released, "bound {bound} != released {released}");
    assert!(
        observed_cancel.load(Ordering::SeqCst),
        "OP never observed the cancel token"
    );
    assert!(
        !ran_to_completion.load(Ordering::SeqCst),
        "OP ran to completion despite the timeout"
    );
}

#[test]
fn two_thousand_node_dag_splits_across_three_backends_capacity_aware() {
    use dflow::bench_util::ConcurrencyProbe;
    use dflow::engine::{Backend, BackendCapacity};
    use dflow::executor::{DispatcherExecutor, LocalExecutor, ProbeExecutor};
    use dflow::hpc::{HpcScheduler, PartitionSpec};

    let cluster = Arc::new(Cluster::uniform(4, Resources::cpu(1000), 0));
    let slurm = HpcScheduler::new(vec![PartitionSpec::new("batch", 4, Duration::from_secs(60))]);
    let (pk, ph, pe) =
        (ConcurrencyProbe::new(), ConcurrencyProbe::new(), ConcurrencyProbe::new());
    let engine = Engine::builder()
        .backend(Backend::custom(
            "k8s",
            Arc::new(ProbeExecutor::new(Arc::new(LocalExecutor), pk.clone())),
            BackendCapacity::Cluster(cluster.clone()),
        ))
        .backend(Backend::custom(
            "hpc",
            Arc::new(ProbeExecutor::new(
                Arc::new(DispatcherExecutor::new(slurm.clone(), "batch")),
                ph.clone(),
            )),
            BackendCapacity::Partition { sched: slurm.clone(), partition: "batch".into() },
        ))
        .backend(Backend::custom(
            "edge",
            Arc::new(ProbeExecutor::new(Arc::new(LocalExecutor), pe.clone())),
            BackendCapacity::Slots(6),
        ))
        .parallelism(32)
        .build();
    let op = Arc::new(FnOp::new(
        Signature::new().out_param("v", ParamType::Int),
        |ctx| {
            ctx.set("v", 1i64);
            Ok(())
        },
    ));
    let names = ["k8s", "hpc", "edge"];
    let mut dag = Dag::new("main");
    for i in 0..2001 {
        dag = dag.task(Step::new(&format!("t{i}"), "op").on_backend(names[i % 3]));
    }
    let wf = Workflow::new("split")
        // cpu(1000) fills one cluster node per pod → k8s concurrency cap 4
        .container(ContainerTemplate::new("op", op).resources(Resources::cpu(1000)))
        .dag(dag)
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.run.count_phase(NodePhase::Succeeded), 2001);
    let split = r.run.placements();
    assert_eq!(split["k8s"], 667);
    assert_eq!(split["hpc"], 667);
    assert_eq!(split["edge"], 667);
    // per-backend in-flight peaks stay within each backend's capacity
    assert!(pk.peak() <= 4, "k8s peak {} > 4 cluster nodes", pk.peak());
    assert!(ph.peak() <= 4, "hpc peak {} > 4 partition slots", ph.peak());
    assert!(pe.peak() <= 6, "edge peak {} > 6 slots", pe.peak());
    let (bound, released, peak_pods) = cluster.stats();
    assert_eq!(bound, 667);
    assert_eq!(bound, released);
    assert!(peak_pods <= 4, "cluster peak {peak_pods} > 4");
    assert_eq!(cluster.pods_in_flight(), 0);
    assert_eq!(slurm.inflight(), 0);
    for s in engine.backend_stats() {
        assert_eq!(s.inflight, 0, "{} stranded a lease", s.name);
    }
}

#[test]
fn timeout_with_queued_retries_keeps_accounting_balanced() {
    // timeout marked transient + retries: every attempt's pod must be
    // returned, including the cancelled ones
    let cluster = Arc::new(Cluster::uniform(1, Resources::cpu(1000), 0));
    let op = Arc::new(FnOp::new(
        Signature::new().out_param("ok", ParamType::Bool),
        move |ctx| {
            for _ in 0..200 {
                ctx.checkpoint()?;
                std::thread::sleep(Duration::from_millis(5));
            }
            ctx.set("ok", true);
            Ok(())
        },
    ));
    let mut policy = StepPolicy::default();
    policy.timeout = Some(Duration::from_millis(30));
    policy.timeout_transient = true;
    policy.retries = 2;
    let wf = Workflow::new("timeout-retry")
        .container(ContainerTemplate::new("slow", op).resources(Resources::cpu(1000)))
        .steps(Steps::new("main").then(Step::new("s", "slow").policy(policy)))
        .entrypoint("main");
    let engine = Engine::builder().cluster(cluster.clone()).build();
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());
    assert_eq!(r.run.metrics.timeouts.get(), 3); // initial + 2 retries
    let mut drained = false;
    for _ in 0..400 {
        let (bound, released, _) = cluster.stats();
        if bound == released && cluster.pods_in_flight() == 0 {
            drained = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(drained, "pod accounting never rebalanced: {:?}", cluster.stats());
}

/// Current OS thread count of this process (`/proc/self/status`); 0 when
/// the proc filesystem is unavailable (non-Linux).
fn os_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// 10k timed attempts arm, cancel and drain on the engine's single
/// timer-wheel thread: OS threads stay bounded by the pool size plus a
/// constant (never one watchdog thread per attempt), every deadline
/// settles exactly once, and nothing is left armed afterwards.
#[test]
fn ten_thousand_timed_attempts_keep_threads_bounded_by_the_pool() {
    const N: usize = 10_000;
    const POOL: usize = 64;
    let op = Arc::new(FnOp::new(
        Signature::new().out_param("v", ParamType::Int),
        |ctx| {
            ctx.set("v", 1i64);
            Ok(())
        },
    ));
    let mut policy = StepPolicy::default();
    // generous: the deadlines must all be cancelled, never fire
    policy.timeout = Some(Duration::from_secs(30));
    let mut dag = Dag::new("main");
    for i in 0..N {
        dag = dag.task(Step::new(&format!("t{i}"), "op").policy(policy.clone()));
    }
    let wf = Workflow::new("timed-flood")
        .container(ContainerTemplate::new("op", op))
        .dag(dag)
        .entrypoint("main");
    let engine = Engine::builder().parallelism(POOL).build();

    // sample the process thread count while the flood runs: the bound
    // must hold mid-flight, not just after the pool drains
    let base_threads = os_threads();
    let peak_threads = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let (peak, stop) = (Arc::clone(&peak_threads), Arc::clone(&stop));
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                peak.fetch_max(os_threads(), Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };
    let r = engine.run(&wf).unwrap();
    stop.store(true, Ordering::Relaxed);
    sampler.join().unwrap();

    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.run.count_phase(NodePhase::Succeeded), N);

    let stats = engine.scheduler_stats();
    assert_eq!(
        stats.timers_cancelled,
        N as u64,
        "every completed attempt must disarm its deadline exactly once: {stats:?}"
    );
    assert_eq!(stats.timers_fired, 0, "a 30s deadline fired under a trivial OP: {stats:?}");
    assert!(
        stats.timer_peak_depth <= POOL as u64,
        "armed deadlines exceeded in-flight attempts: {stats:?}"
    );
    let peak = peak_threads.load(Ordering::Relaxed);
    if peak > 0 {
        // pool workers + main + sampler + the one timer thread + slack:
        // the thread budget must not scale with the number of timed
        // attempts
        assert!(
            peak <= base_threads + POOL + 8,
            "thread count scaled with timed attempts: \
             peak {peak} vs baseline {base_threads} + pool {POOL}"
        );
    }
    check::assert_all_drained(&engine, None, None);
}

/// Scaled-down 100k-node smoke (the full-size closer lives in the c1
/// bench): a 10k-node diamond chain completes on 8 pool workers, and the
/// ready-queue counters show diamond joins waking both successors in one
/// batch instead of one queue lock per ready task.
#[test]
fn ten_thousand_node_dag_smoke_coalesces_successor_wakeups() {
    let (wf, probe, nodes) = diamond_chain_workflow(10_002, 8);
    let engine = Engine::builder().parallelism(8).build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.run.count_phase(NodePhase::Succeeded), nodes);
    assert!(
        probe.peak() <= 8,
        "peak live workers {} exceeded parallelism 8",
        probe.peak()
    );
    let stats = engine.scheduler_stats();
    assert!(
        stats.jobs_submitted >= nodes as u64,
        "every task must pass through the pool: {stats:?}"
    );
    assert!(
        stats.submit_batches < stats.jobs_submitted,
        "wakeups must coalesce (fewer queue publishes than jobs): {stats:?}"
    );
    check::assert_all_drained(&engine, None, None);
}
