//! Observability battery (ISSUE 9): end-to-end span capture through a
//! journaled engine, `dflow profile` critical-path reconciliation against
//! the journaled run wall-clock, cross-process profiles after a journal
//! reopen + compaction, and a hand-written Prometheus text-format
//! line-grammar validator over both exporters (engine and service).
//!
//! Run via `make test-obs` (part of `make ci`).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use dflow::core::{ContainerTemplate, FnOp, ParamType, Signature, Step, Steps, Workflow};
use dflow::engine::Engine;
use dflow::journal::{Journal, JournalEvent, RunRegistry};
use dflow::obs::Phase;
use dflow::service::{ServiceConfig, WorkflowService};
use dflow::storage::MemStorage;

/// A serial 3-step chain, each step sleeping `step_ms` — the critical
/// path IS the whole workflow, so profile reconciliation is exact.
fn serial_chain(step_ms: u64) -> Workflow {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        move |ctx| {
            let x = ctx.get_int("x")?;
            std::thread::sleep(Duration::from_millis(step_ms));
            ctx.set("y", x + 1);
            Ok(())
        },
    ));
    Workflow::new("chain")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(Step::new("a", "op").param("x", 0i64))
                .then(Step::new("b", "op").param_from_step("x", "a", "y"))
                .then(Step::new("c", "op").param_from_step("x", "b", "y"))
                .out_param_from("r", "c", "y"),
        )
        .entrypoint("main")
}

#[test]
fn spans_flow_end_to_end_through_a_journaled_engine() {
    let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
    let engine = Engine::builder().journal(Arc::clone(&journal)).build();
    let r = engine.run(&serial_chain(5)).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);

    // telemetry is on by default: every attempt closed a span bundle
    let rec = r.run.spans().expect("telemetry must be on by default");
    let spans = rec.snapshot();
    let node_spans: Vec<_> = spans.iter().filter(|s| !s.path.is_empty()).collect();
    assert_eq!(node_spans.len(), 3, "one bundle per attempt: {spans:?}");
    for s in &node_spans {
        let phases: Vec<Phase> = s.segs.iter().map(|g| g.phase).collect();
        assert!(phases.contains(&Phase::ReadyWait), "missing ready_wait: {phases:?}");
        assert!(phases.contains(&Phase::OpExec), "missing op_exec: {phases:?}");
        let exec = s.segs.iter().find(|g| g.phase == Phase::OpExec).unwrap();
        assert!(exec.dur_us >= 4_000, "5ms sleep measured as {}µs", exec.dur_us);
    }
    // the run-level accumulator bundle carries admission + journal appends
    let run_bundle = spans.iter().find(|s| s.path.is_empty()).expect("run-level bundle");
    let phases: Vec<Phase> = run_bundle.segs.iter().map(|g| g.phase).collect();
    assert!(phases.contains(&Phase::Admission), "admission cost missing: {phases:?}");
    assert!(phases.contains(&Phase::JournalAppend), "append cost missing: {phases:?}");

    // every bundle was mirrored into the journal as SpanClosed
    let (events, torn) = journal.events(r.run.id).unwrap();
    assert!(!torn);
    let journaled: Vec<_> = events
        .iter()
        .filter(|e| matches!(e.event, JournalEvent::SpanClosed { .. }))
        .collect();
    assert_eq!(journaled.len(), spans.len(), "journal mirror count");
}

#[test]
fn telemetry_off_records_nothing() {
    let engine = Engine::builder().telemetry(false).build();
    let r = engine.run(&serial_chain(1)).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert!(r.run.spans().is_none(), "telemetry(false) must not record spans");
}

/// The acceptance criterion: `dflow profile` critical-path duration
/// reconciles with the journaled run wall-clock within 10%.
#[test]
fn profile_critical_path_reconciles_with_run_wall_clock() {
    let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
    let engine = Engine::builder().journal(Arc::clone(&journal)).build();
    let r = engine.run(&serial_chain(100)).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);

    let registry = RunRegistry::new(Arc::clone(&journal));
    let p = registry.profile(r.run.id).unwrap();
    assert_eq!(p.run_id, r.run.id);
    assert_eq!(p.workflow, "chain");
    assert_eq!(p.steps.len(), 3);

    // the chain reconstruction finds the serial a → b → c spine
    let crit: Vec<&str> = p.critical.iter().map(|c| c.path.as_str()).collect();
    assert_eq!(crit, ["main/a", "main/b", "main/c"], "critical path");

    // reconciliation: 3 × 100ms of measured spans vs the journaled wall
    let crit_ms = p.critical_us as f64 / 1e3;
    let wall_ms = p.wall_ms as f64;
    assert!(wall_ms >= 300.0, "wall below payload time: {wall_ms}");
    assert!(
        (crit_ms - wall_ms).abs() <= wall_ms * 0.10,
        "critical path {crit_ms:.1}ms vs wall {wall_ms:.1}ms diverges >10%"
    );

    // phase totals: op_exec dominates a sleep-bound chain
    let exec = p.phases.iter().find(|t| t.phase == Phase::OpExec).unwrap();
    assert!(exec.total_us >= 300_000, "op_exec total {}µs", exec.total_us);
    assert_eq!(exec.count, 3);

    // both renderings carry the reconciled numbers
    let j = p.to_json();
    assert_eq!(j.get("critical_path").unwrap().as_arr().unwrap().len(), 3);
    assert!(p.render_text().contains("critical path"));
}

#[test]
fn profiles_survive_journal_reopen_and_compaction() {
    let storage = Arc::new(MemStorage::new());
    let run_id = {
        let journal = Arc::new(Journal::open(Arc::clone(&storage)).unwrap());
        let engine = Engine::builder().journal(journal).build();
        let r = engine.run(&serial_chain(20)).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        r.run.id
    };

    // a fresh process sharing the store: reopen, profile, compact, profile
    let journal = Arc::new(Journal::open(storage).unwrap());
    let registry = RunRegistry::new(Arc::clone(&journal));
    let before = registry.profile(run_id).unwrap();
    assert_eq!(before.steps.len(), 3);

    journal.compact(run_id).unwrap();
    let after = registry.profile(run_id).unwrap();
    assert_eq!(after.critical_us, before.critical_us, "compaction changed the profile");
    assert_eq!(after.steps.len(), 3);
    let crit: Vec<&str> = after.critical.iter().map(|c| c.path.as_str()).collect();
    assert_eq!(crit, ["main/a", "main/b", "main/c"]);
}

// ---------------------------------------------------------------------------
// Prometheus text exposition format: hand-written line-grammar validator
// (no deps). Checks: HELP/TYPE headers are well-formed and unique, every
// sample line parses (name, optional escaped label set, float value),
// every sample belongs to a TYPE'd family (summary `_sum`/`_count`
// suffixes resolve to their base family), and counters are non-negative.
// ---------------------------------------------------------------------------

fn is_metric_name(s: &str) -> bool {
    !s.is_empty()
        && !s.starts_with(|c: char| c.is_ascii_digit())
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Split a sample line into (name, labels, value-text).
fn parse_sample(line: &str) -> Result<(String, Vec<(String, String)>, String), String> {
    let name_end = line
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == ':'))
        .unwrap_or(line.len());
    let name = &line[..name_end];
    if !is_metric_name(name) {
        return Err(format!("bad metric name in: {line}"));
    }
    let mut rest = &line[name_end..];
    let mut labels = Vec::new();
    if let Some(stripped) = rest.strip_prefix('{') {
        // scan to the closing brace, honoring \" escapes inside values
        let (mut in_quotes, mut escaped, mut end) = (false, false, None);
        for (i, c) in stripped.char_indices() {
            match (in_quotes, escaped, c) {
                (true, true, _) => escaped = false,
                (true, false, '\\') => escaped = true,
                (true, false, '"') => in_quotes = false,
                (false, _, '"') => in_quotes = true,
                (false, _, '}') => {
                    end = Some(i);
                    break;
                }
                _ => {}
            }
        }
        let end = end.ok_or_else(|| format!("unclosed label set in: {line}"))?;
        for pair in split_label_pairs(&stripped[..end])? {
            let (k, v) = pair;
            if !is_metric_name(&k) {
                return Err(format!("bad label name '{k}' in: {line}"));
            }
            labels.push((k, v));
        }
        rest = &stripped[end + 1..];
    }
    let value = rest
        .strip_prefix(' ')
        .ok_or_else(|| format!("missing value separator in: {line}"))?;
    Ok((name.to_string(), labels, value.to_string()))
}

/// Split `k1="v1",k2="v2"` into pairs (values keep escapes resolved).
fn split_label_pairs(s: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    let mut rest = s;
    while !rest.is_empty() {
        let eq = rest.find('=').ok_or_else(|| format!("label without '=': {s}"))?;
        let key = rest[..eq].to_string();
        let after = rest[eq + 1..]
            .strip_prefix('"')
            .ok_or_else(|| format!("unquoted label value: {s}"))?;
        let (mut val, mut escaped, mut close) = (String::new(), false, None);
        for (i, c) in after.char_indices() {
            if escaped {
                val.push(c);
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                close = Some(i);
                break;
            } else {
                val.push(c);
            }
        }
        let close = close.ok_or_else(|| format!("unterminated label value: {s}"))?;
        out.push((key, val));
        rest = &after[close + 1..];
        if let Some(r) = rest.strip_prefix(',') {
            rest = r;
        } else if !rest.is_empty() {
            return Err(format!("junk between labels: {s}"));
        }
    }
    Ok(out)
}

/// Validate a whole exposition document; returns family → sample count.
fn validate_prometheus(text: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap_or("");
            if !is_metric_name(name) {
                return Err(format!("bad HELP name: {line}"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            if !is_metric_name(name) {
                return Err(format!("bad TYPE name: {line}"));
            }
            if !["counter", "gauge", "summary", "histogram", "untyped"].contains(&kind) {
                return Err(format!("unknown TYPE kind: {line}"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return Err(format!("duplicate TYPE for {name}"));
            }
            continue;
        }
        if line.starts_with('#') {
            return Err(format!("unexpected comment line: {line}"));
        }
        let (name, labels, value) = parse_sample(line)?;
        let v: f64 = value.parse().map_err(|_| format!("bad value in: {line}"))?;
        // resolve the sample to its family (summaries emit _sum/_count)
        let family = if types.contains_key(&name) {
            name.clone()
        } else {
            let base = name
                .strip_suffix("_sum")
                .or_else(|| name.strip_suffix("_count"))
                .ok_or_else(|| format!("sample without TYPE header: {line}"))?;
            if types.get(base).map(String::as_str) != Some("summary") {
                return Err(format!("suffix sample outside a summary family: {line}"));
            }
            base.to_string()
        };
        let kind = types[&family].as_str();
        if kind == "counter" && v < 0.0 {
            return Err(format!("negative counter: {line}"));
        }
        if labels.iter().any(|(k, _)| k == "quantile") && kind != "summary" {
            return Err(format!("quantile label outside a summary: {line}"));
        }
        *counts.entry(family).or_insert(0) += 1;
    }
    if counts.is_empty() {
        return Err("no samples".to_string());
    }
    Ok(counts)
}

#[test]
fn grammar_validator_rejects_malformed_documents() {
    assert!(validate_prometheus("").is_err(), "empty doc has no samples");
    assert!(
        validate_prometheus("# TYPE x counter\nx{tenant=\"a} 1\n").is_err(),
        "unclosed label value"
    );
    assert!(validate_prometheus("x 1\n").is_err(), "sample without TYPE");
    assert!(
        validate_prometheus("# TYPE x counter\nx -1\n").is_err(),
        "negative counter"
    );
    assert!(
        validate_prometheus("# TYPE x counter\n# TYPE x counter\nx 1\n").is_err(),
        "duplicate TYPE"
    );
    assert!(
        validate_prometheus("# TYPE x gauge\nx{quantile=\"0.5\"} 1\n").is_err(),
        "quantile on a gauge"
    );
    let ok = "# HELP x help text\n# TYPE x summary\nx{quantile=\"0.5\"} 0.1\nx_sum 1\nx_count 2\n";
    assert_eq!(validate_prometheus(ok).unwrap()["x"], 3);
}

#[test]
fn engine_export_is_valid_prometheus_with_live_latency_tails() {
    let engine = Engine::builder().build();
    let r = engine.run(&serial_chain(5)).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);

    let text = engine.export_metrics().to_prometheus();
    let families = validate_prometheus(&text).unwrap_or_else(|e| panic!("invalid export: {e}"));

    // the run's counters and latency summaries are all present
    for name in [
        "dflow_steps_succeeded_total",
        "dflow_op_exec_seconds",
        "dflow_dispatch_seconds",
        "dflow_sched_jobs_total",
        "dflow_sched_queue_wait_seconds",
    ] {
        assert!(families.contains_key(name), "family {name} missing:\n{text}");
    }
    assert!(text.contains("dflow_steps_succeeded_total 3\n"), "fleet counter:\n{text}");
    // op_exec saw 3 × 5ms sleeps: the summary's _count says so
    assert!(text.contains("dflow_op_exec_seconds_count 3\n"), "summary count:\n{text}");

    // JSON rendering parses and mirrors the same families
    let json = engine.export_metrics().to_json().to_string_pretty();
    let parsed = dflow::jsonx::Json::parse(&json).unwrap();
    let fams = parsed.get("families").unwrap().as_arr().unwrap();
    assert!(fams.len() >= families.len());
}

#[test]
fn service_export_and_top_reflect_the_fleet() {
    let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
    let engine = Arc::new(Engine::builder().journal(journal).build());
    let svc = WorkflowService::start(engine, ServiceConfig::default()).unwrap();
    for tenant in ["alice", "bob"] {
        svc.submit(tenant, serial_chain(5)).unwrap();
    }
    assert!(svc.wait_idle(Duration::from_secs(30)), "service never drained");

    let text = svc.export_metrics().to_prometheus();
    let families = validate_prometheus(&text).unwrap_or_else(|e| panic!("invalid export: {e}"));
    // per-tenant labeled series share one family
    assert_eq!(families["dflow_svc_submitted_total"], 2, "one series per tenant:\n{text}");
    assert!(text.contains("dflow_svc_submitted_total{tenant=\"alice\"} 1\n"), "{text}");
    assert!(text.contains("dflow_svc_succeeded_total{tenant=\"bob\"} 1\n"), "{text}");
    // control-plane latency summaries observed both runs
    assert!(text.contains("dflow_svc_queue_wait_seconds_count 2\n"), "{text}");
    assert!(text.contains("dflow_svc_run_seconds_count 2\n"), "{text}");
    // the engine families ride along in the same document
    assert!(families.contains_key("dflow_steps_succeeded_total"), "{text}");

    // `dflow top`: fleet is idle again, but the latency summaries persist
    let top = svc.top_json();
    assert_eq!(top.get("live").unwrap().as_arr().unwrap().len(), 0);
    assert_eq!(top.get("queued").unwrap().as_i64(), Some(0));
    let qw = top.get("queue_wait").unwrap();
    assert_eq!(qw.get("count").unwrap().as_i64(), Some(2));
    // the per-tenant JSON surface gained the same summaries
    let mj = svc.metrics().to_json();
    assert_eq!(mj.get("run_duration").unwrap().get("count").unwrap().as_i64(), Some(2));
}
