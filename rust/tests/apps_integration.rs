//! Integration: the §3 application workflows end-to-end over the engine +
//! PJRT runtime. These are the repository's "the paper's workflows actually
//! run and produce physically sensible numbers" tests.
//!
//! Every test skips cleanly when `artifacts/` is absent.

use std::sync::Arc;

use dflow::apps::{apex, deepks, fpop, rid, tesla, vsw};
use dflow::cluster::{Cluster, NodeSpec, Resources};
use dflow::core::Value;
use dflow::engine::Engine;
use dflow::runtime::Runtime;

macro_rules! engine_or_skip {
    () => {
        match Runtime::global() {
            Some(rt) => Engine::builder().runtime(rt).build(),
            None => {
                eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// A small heterogeneous cluster matching the apps' resource requests.
fn gpu_cluster() -> Arc<Cluster> {
    let mut nodes: Vec<NodeSpec> = (0..4)
        .map(|i| NodeSpec::worker(format!("cpu-{i}"), Resources::new(16_000, 32_000, 0)))
        .collect();
    for i in 0..4 {
        nodes.push(
            NodeSpec::worker(format!("gpu-{i}"), Resources::new(16_000, 32_000, 4))
                .label("accel", "gpu"),
        );
    }
    Arc::new(Cluster::new(nodes, 0))
}

#[test]
fn fpop_eos_flow_recovers_lattice_constant() {
    let engine = engine_or_skip!();
    let scales = [0.85, 0.9, 0.95, 1.0, 1.05, 1.1, 1.15];
    let wf = fpop::eos_workflow(7, &scales, 2);
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let v0 = r.outputs.params["v0"].as_float().unwrap();
    let e0 = r.outputs.params["e0"].as_float().unwrap();
    let b0 = r.outputs.params["b0"].as_float().unwrap();
    // relaxed LJ sc-cluster: equilibrium scale^3 interior, cohesive E < 0
    assert!(v0 > 0.6 && v0 < 1.6, "v0={v0}");
    assert!(e0 < -100.0, "e0={e0}");
    assert!(b0 > 0.0, "b0={b0}");
    // all 7 fp tasks ran and are queryable by key (§2.5)
    for i in 0..7 {
        assert!(r.query_step(&format!("fp-{i}")).is_some(), "fp-{i} missing");
    }
}

#[test]
fn apex_joint_job_produces_properties() {
    let engine = engine_or_skip!();
    let scales = [0.85, 0.9, 0.95, 1.0, 1.05, 1.1, 1.15];
    let wf = apex::joint_workflow(3, &scales);
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let e_coh = r.outputs.params["e_cohesive"].as_float().unwrap();
    let relax_e = r.outputs.params["relax_energy"].as_float().unwrap();
    assert!(e_coh < -1.0 && e_coh > -10.0, "e_cohesive={e_coh}");
    assert!(relax_e < -100.0);
    // relaxation must lower the energy vs the jittered start
    let b0 = r.outputs.params["b0"].as_float().unwrap();
    assert!(b0 > 0.0);
}

#[test]
fn tesla_loop_trains_and_converges_iterations() {
    let engine = engine_or_skip!();
    let cfg = tesla::TeslaConfig {
        n_models: 2,
        n_walkers: 2,
        md_calls: 2,
        train_steps: 30,
        max_iters: 2,
        init_configs: 4,
        ..Default::default()
    };
    let wf = tesla::workflow(&cfg, 1);
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let trace = tesla::convergence_trace(&r.run, &cfg);
    assert!(!trace.is_empty(), "no iterations recorded");
    for it in &trace {
        assert!(it.mean_loss.is_finite() && it.mean_loss >= 0.0);
        assert!(it.max_devi.is_finite());
    }
    // every ensemble member of iteration 0 trained
    for m in 0..cfg.n_models {
        assert!(r.query_step(&format!("train-0-{m}")).is_some());
    }
}

#[test]
fn deepks_loop_obeys_breaking_condition() {
    let engine = engine_or_skip!();
    let cfg = deepks::DeepksConfig {
        n_systems: 4,
        train_steps: 25,
        max_iters: 2,
        conv_loss: 1e-9, // never converges -> runs max_iters
        ..Default::default()
    };
    let wf = deepks::workflow(&cfg);
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    // exactly max_iters TRAIN sections executed
    assert!(r.run.query_step("train-0").is_some());
    assert!(r.run.query_step("train-1").is_some());
    assert!(r.run.query_step("train-2").is_none());
    // SCF fault tolerance: some slices may have failed but the loop survived
    let loss1 = r.run.query_step("train-1").unwrap().outputs.params["final_loss"]
        .as_float()
        .unwrap();
    assert!(loss1.is_finite());
}

#[test]
fn rid_blocks_chain_and_update_models() {
    let engine = engine_or_skip!();
    let cfg = rid::RidConfig {
        n_walkers: 2,
        md_calls: 2,
        n_train: 2,
        train_steps: 20,
        iterations: 1,
        label_parallelism: 4,
        ..Default::default()
    };
    let wf = rid::workflow(&cfg, 5);
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    // init ensemble + block-0 ensemble both trained
    assert!(r.query_step("train-init-0").is_some());
    assert!(r.query_step("train-0-0").is_some());
    assert!(r.query_step("train-0-1").is_some());
}

#[test]
fn vsw_funnel_narrows_and_improves_scores() {
    let engine = engine_or_skip!();
    let cfg = vsw::VswConfig {
        n_shards: 6,
        k1: 512,
        k2: 256,
        parallelism: 16,
        ..Default::default()
    };
    let wf = vsw::workflow(&cfg, 99);
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let best = r.outputs.params["best"].as_float().unwrap();
    let mean = r.outputs.params["mean"].as_float().unwrap();
    let cutoff1 = r.outputs.params["cutoff1"].as_float().unwrap();
    let cutoff2 = r.outputs.params["cutoff2"].as_float().unwrap();
    assert!(best <= mean, "best {best} vs mean {mean}");
    // funnel property: the stage-2 cutoff is at least as selective
    assert!(cutoff2 <= cutoff1 + 1.0, "cutoffs {cutoff1} -> {cutoff2}");
    // per-shard keys exist for restart
    assert!(r.query_step("dock-0").is_some());
}

#[test]
fn vsw_funnel_on_gpu_cluster_with_restart() {
    let rt = match Runtime::global() {
        Some(rt) => rt,
        None => {
            eprintln!("SKIP: artifacts/ not built");
            return;
        }
    };
    let cluster = gpu_cluster();
    let engine = Engine::builder().runtime(rt).cluster(cluster.clone()).build();
    let cfg = vsw::VswConfig { n_shards: 4, k1: 256, k2: 128, parallelism: 8, ..Default::default() };
    // vsw-dock requests a GPU; the cluster has 16 — expect full completion
    let wf = vsw::workflow(&cfg, 5);
    let r1 = engine.run(&wf).unwrap();
    assert!(r1.succeeded(), "{:?}", r1.error);
    let (bound, released, peak) = cluster.stats();
    assert_eq!(bound, released);
    assert!(peak <= 16);
    // restart with full reuse: no new docking work
    let reuse = r1.run.all_keyed();
    let r2 = engine.run_with_reuse(&wf, reuse).unwrap();
    assert!(r2.succeeded());
    assert!(r2.run.metrics.steps_reused.get() >= 4, "reused {}", r2.run.metrics.steps_reused.get());
}

#[test]
fn tesla_on_heterogeneous_cluster_uses_gpu_nodes() {
    let rt = match Runtime::global() {
        Some(rt) => rt,
        None => {
            eprintln!("SKIP: artifacts/ not built");
            return;
        }
    };
    let cluster = gpu_cluster();
    let engine = Engine::builder().runtime(rt).cluster(cluster.clone()).build();
    let cfg = tesla::TeslaConfig {
        n_models: 2,
        n_walkers: 2,
        md_calls: 1,
        train_steps: 10,
        max_iters: 1,
        init_configs: 2,
        ..Default::default()
    };
    let r = engine.run(&tesla::workflow(&cfg, 2)).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    // train/explore pods must have landed on gpu-labeled nodes
    let gpu_pods = r
        .run
        .trace
        .snapshot()
        .into_iter()
        .filter(|e| {
            matches!(e.kind, dflow::metrics::EventKind::PodBound) && e.detail.starts_with("gpu-")
        })
        .count();
    assert!(gpu_pods > 0, "no pods on GPU nodes");
}

#[test]
fn tesla_reuse_resumes_training_cheaply() {
    let engine = engine_or_skip!();
    let cfg = tesla::TeslaConfig {
        n_models: 2,
        n_walkers: 2,
        md_calls: 1,
        train_steps: 15,
        max_iters: 1,
        init_configs: 2,
        ..Default::default()
    };
    let wf = tesla::workflow(&cfg, 4);
    let r1 = engine.run(&wf).unwrap();
    assert!(r1.succeeded(), "{:?}", r1.error);
    let t0 = std::time::Instant::now();
    let r2 = engine.run_with_reuse(&wf, r1.run.all_keyed()).unwrap();
    let resumed = t0.elapsed();
    assert!(r2.succeeded());
    assert!(r2.run.metrics.steps_reused.get() >= 4);
    // reused run skips all training/exploration: much faster
    eprintln!("resumed in {resumed:?}");
    let fresh_exec = r1.run.metrics.op_exec.total();
    assert!(resumed < fresh_exec, "resume {resumed:?} !< fresh work {fresh_exec:?}");
}

#[test]
fn apex_property_workflow_on_uploaded_artifact() {
    let rt = match Runtime::global() {
        Some(rt) => rt,
        None => {
            eprintln!("SKIP: artifacts/ not built");
            return;
        }
    };
    let engine = Engine::builder().runtime(rt).build();
    // upload a relaxed-ish configuration as the workflow input artifact
    let x = dflow::runtime::Tensor::new(
        vec![64, 3],
        dflow::science::lj::lattice(64, 1.07, 0.0, 0),
    )
    .unwrap();
    engine.storage.upload("inputs/relaxed", &x.to_bytes()).unwrap();
    let scales = [0.9, 0.95, 1.0, 1.05, 1.1, 1.15, 1.2];
    let wf = apex::property_workflow(&scales)
        .input_artifact("relaxed", dflow::core::ArtifactRef::new("inputs/relaxed"));
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert!(r.outputs.params["b0"].as_float().unwrap() > 0.0);
    assert_eq!(
        r.outputs.params["e_cohesive"].type_of(),
        dflow::core::ParamType::Float
    );
    let _ = Value::Null; // keep import used
}
