//! Chaos battery (ISSUE 7): deterministic fault injection across the
//! placement / engine / service stack.
//!
//! Every case drives a real workflow while a seeded [`ChaosPlan`] fires
//! faults at exact event boundaries — backend kills mid-fan-out, full
//! cordon windows, HPC partition capacity flaps, priority preemption —
//! and every case ends the same way: the run reaches a terminal state
//! with a *named* cause (never a hang), and `check::assert_all_drained`
//! proves nothing leaked: no leases, pods, partition jobs, blocked
//! workers or cached journal writers survive.
//!
//! Run via `make test-chaos` (part of `make ci`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use dflow::check;
use dflow::check::chaos::{ChaosAction, ChaosPlan};
use dflow::core::{
    ContainerTemplate, Dag, FnOp, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::{Backend, BackendHealth, Engine, NodePhase, Priority, SubmitOptions};
use dflow::hpc::{HpcScheduler, PartitionSpec};
use dflow::journal::{Journal, JournalEvent};
use dflow::metrics::EventKind;
use dflow::service::{ServiceConfig, WorkflowService};
use dflow::storage::{MemStorage, StorageClient};

/// Poll `cond` up to 5 s; panic with `what` if it never turns true.
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(5), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// A `width`-slice fan-out of briefly-sleeping square ops.
fn fanout_workflow(n: i64, parallelism: usize) -> Workflow {
    let sq = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            let x = ctx.get_int("x")?;
            std::thread::sleep(Duration::from_micros(300));
            ctx.set("y", x * x);
            Ok(())
        },
    ));
    Workflow::new("chaos-fanout")
        .container(ContainerTemplate::new("sq", sq))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "sq")
                        .param("x", Value::ints(0..n))
                        .slices(Slices::over("x").stack("y").parallelism(parallelism)),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main")
}

/// Acceptance: 3 backends, a 2000-slice fan-out, one backend killed at a
/// fixed mid-run event boundary. Its in-flight attempts fail over
/// (journaled, budget-free), the survivors absorb the rest, the run
/// succeeds with every output intact, and nothing leaks.
#[test]
fn kill_one_of_three_backends_mid_fanout_fails_over_and_completes() {
    let storage: Arc<dyn StorageClient> = Arc::new(MemStorage::new());
    let journal = Arc::new(Journal::open(storage.clone()).unwrap().segment_max_bytes(8192));
    let engine = Engine::builder()
        .storage(storage)
        .journal(journal.clone())
        .backend(Backend::local_slots("b0", 16))
        .backend(Backend::local_slots("b1", 16))
        .backend(Backend::local_slots("b2", 16))
        .parallelism(32)
        .adaptive_cap(128)
        .build();
    let plan = ChaosPlan::new();
    let b0 = Arc::clone(engine.placer().unwrap().backend("b0").unwrap());
    // ~2 boundaries per slice (placement + job dispatch): boundary 800 is
    // deep inside the fan-out, with b0 saturated at 16 in-flight attempts
    plan.at(800, ChaosAction::KillBackend(Arc::clone(&b0)));
    plan.install(&engine);

    let r = engine.run(&fanout_workflow(2000, 64)).unwrap();
    assert!(r.succeeded(), "failover must keep the run alive: {:?}", r.error);
    let ys = r.outputs.params["ys"].as_list().unwrap();
    assert_eq!(ys.len(), 2000);
    assert_eq!(ys[1999], Value::Int(1999 * 1999));

    assert_eq!(plan.pending(), 0, "the kill never fired");
    assert_eq!(b0.health(), BackendHealth::Dead);
    let failovers = r.run.metrics.failovers.get();
    assert!(
        failovers >= 1,
        "a saturated backend died mid-run; its in-flight attempts must fail over"
    );
    assert!(
        r.run
            .trace
            .snapshot()
            .iter()
            .any(|e| e.kind == EventKind::StepFailedOver),
        "failover must be traced"
    );
    // every slice placed at least once; voided attempts re-placed on the
    // two survivors
    let split = r.run.placements();
    assert!(split.values().sum::<u64>() >= 2000 + failovers);
    assert!(split.get("b1").copied().unwrap_or(0) > 0);
    assert!(split.get("b2").copied().unwrap_or(0) > 0);
    // the failovers are journaled with the dead backend named
    let (events, torn) = journal.events(r.run.id).unwrap();
    assert!(!torn);
    let journaled = events
        .iter()
        .filter(|rec| {
            matches!(&rec.event, JournalEvent::NodeFailedOver { backend, .. } if backend == "b0")
        })
        .count() as u64;
    assert_eq!(journaled, failovers, "every failover must be journaled");
    check::assert_all_drained(&engine, None, Some(&journal));
}

/// Cordoning every backend is a drain, not a death: placements wait out
/// the window (no failovers, no failures) and the fan-out completes once
/// the cordon lifts at a later chaos boundary.
#[test]
fn cordon_all_backends_then_uncordon_waits_without_failing() {
    let engine = Engine::builder()
        .backend(Backend::local_slots("b0", 2))
        .backend(Backend::local_slots("b1", 2))
        .parallelism(8)
        .build();
    let plan = ChaosPlan::new();
    let b0 = Arc::clone(engine.placer().unwrap().backend("b0").unwrap());
    let b1 = Arc::clone(engine.placer().unwrap().backend("b1").unwrap());
    plan.at(10, ChaosAction::CordonBackend(Arc::clone(&b0)));
    plan.at(11, ChaosAction::CordonBackend(Arc::clone(&b1)));
    // blocked placements keep polling (one boundary per 25 ms re-poll), so
    // the uncordon boundary is reached even with everything drained
    plan.at(40, ChaosAction::UncordonBackend(Arc::clone(&b0)));
    plan.at(41, ChaosAction::UncordonBackend(Arc::clone(&b1)));
    plan.install(&engine);

    let r = engine.run(&fanout_workflow(40, 8)).unwrap();
    assert!(r.succeeded(), "a cordon window must delay, not fail: {:?}", r.error);
    assert_eq!(plan.pending(), 0, "cordon/uncordon actions never fired");
    assert_eq!(r.run.metrics.failovers.get(), 0, "cordon is a drain, not a death");
    assert_eq!(r.run.metrics.evictions.get(), 0);
    assert_eq!(b0.health(), BackendHealth::Alive);
    assert_eq!(b1.health(), BackendHealth::Alive);
    assert_eq!(r.run.placements().values().sum::<u64>(), 40);
    check::assert_all_drained(&engine, None, None);
}

/// An HPC partition's capacity flaps to zero while placements wait on it.
/// Zero *current* slots reads as busy (the spec'd maximum is what decides
/// infeasibility), so waiters hold on and complete when capacity returns.
#[test]
fn partition_capacity_flap_during_placement_wait_recovers() {
    let hpc = HpcScheduler::new(vec![PartitionSpec::new("batch", 3, Duration::from_secs(30))]);
    let engine = Engine::builder()
        .backend(Backend::partition("hpc", Arc::clone(&hpc), "batch"))
        .parallelism(6)
        .build();
    let plan = ChaosPlan::new();
    plan.at(6, ChaosAction::SetPartitionSlots(Arc::clone(&hpc), "batch".into(), 0));
    plan.at(30, ChaosAction::SetPartitionSlots(Arc::clone(&hpc), "batch".into(), 3));
    plan.install(&engine);

    let r = engine.run(&fanout_workflow(12, 6)).unwrap();
    assert!(r.succeeded(), "a capacity flap must delay, not fail: {:?}", r.error);
    assert_eq!(plan.pending(), 0, "flap actions never fired");
    assert_eq!(r.run.metrics.failovers.get(), 0);
    let st = hpc.partition_stats("batch").unwrap();
    assert_eq!(st.slots, 3, "capacity restored");
    assert_eq!(st.submitted, st.completed, "every HPC job must complete");
    check::assert_all_drained(&engine, None, None);
}

/// Acceptance: priority preemption. A normal-priority run holds the only
/// slot; a low-priority run queues behind it; a high-priority run then
/// evicts the queued low-priority placement (journaled, with the evictor
/// named) and takes the freed slot first. The evicted run is only
/// re-queued — it still completes. The *running* lease is never revoked.
#[test]
fn high_priority_evicts_only_queued_low_priority_and_everyone_completes() {
    let storage: Arc<dyn StorageClient> = Arc::new(MemStorage::new());
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Arc::new(
        Engine::builder()
            .storage(storage)
            .journal(journal.clone())
            .backend(Backend::local_slots("box", 1))
            .parallelism(8)
            .build(),
    );
    let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
    let gate = Arc::new(AtomicBool::new(false));

    let wf = |name: &'static str, hold: Option<Arc<AtomicBool>>| {
        let order = Arc::clone(&order);
        let op = Arc::new(FnOp::new(Signature::new(), move |_| {
            order.lock().unwrap().push(name);
            if let Some(g) = &hold {
                while !g.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            Ok(())
        }));
        Workflow::new(name)
            .container(ContainerTemplate::new("op", op))
            .steps(Steps::new("main").then(Step::new("s", "op")))
            .entrypoint("main")
    };

    // the holder occupies the slot until the gate opens
    let holder = engine
        .submit_with_options(wf("holder", Some(Arc::clone(&gate))), SubmitOptions::default())
        .unwrap();
    let placer = engine.placer().unwrap();
    let slot = placer.backend("box").unwrap();
    wait_until("the holder to occupy the slot", || slot.inflight() == 1);

    // a low-priority run queues behind the held slot
    let low = engine
        .submit_with_options(
            wf("low", None),
            SubmitOptions { priority: Priority::Low, ..SubmitOptions::default() },
        )
        .unwrap();
    wait_until("the low-priority placement to queue", || placer.waiting() >= 1);

    // the high-priority run preempts the queued (never the running) one
    let high = engine
        .submit_with_options(
            wf("high", None),
            SubmitOptions { priority: Priority::High, ..SubmitOptions::default() },
        )
        .unwrap();
    wait_until("the eviction to land", || low.run.metrics.evictions.get() >= 1);

    gate.store(true, Ordering::SeqCst);
    let (high_id, low_id) = (high.run.id, low.run.id);
    let rh = holder.wait();
    let rhigh = high.wait();
    let rlow = low.wait();
    assert!(rh.succeeded() && rhigh.succeeded() && rlow.succeeded());
    assert_eq!(
        *order.lock().unwrap(),
        vec!["holder", "high", "low"],
        "freed capacity must go to the high class first"
    );

    // the eviction names its evictor, in the trace and in the journal
    assert_eq!(rlow.run.metrics.evictions.get(), 1);
    let evictor = format!("run {high_id}");
    assert!(
        rlow.run
            .trace
            .snapshot()
            .iter()
            .any(|e| e.kind == EventKind::StepEvicted && e.detail == evictor),
        "eviction must be traced with the evictor named"
    );
    let (events, _) = journal.events(low_id).unwrap();
    assert!(
        events.iter().any(|rec| matches!(
            &rec.event,
            JournalEvent::NodeEvicted { path, by, .. } if path == "main/s" && *by == evictor
        )),
        "eviction must be journaled with the evictor named"
    );
    // the running holder was never preempted, nobody failed over
    assert_eq!(rh.run.metrics.evictions.get(), 0);
    assert_eq!(rhigh.run.metrics.evictions.get(), 0);
    for r in [&rh, &rhigh, &rlow] {
        assert_eq!(r.run.metrics.failovers.get(), 0);
    }
    check::assert_all_drained(&engine, None, Some(&journal));
}

/// When every matching backend is dead, a failover-exhausted run fails
/// with the named `BackendsDead` cause — it never hangs — and after the
/// backends revive, resubmitting the same run id re-runs exactly the
/// non-succeeded suffix.
#[test]
fn all_backends_dead_fails_with_named_cause_and_recovers_on_revival() {
    let storage: Arc<dyn StorageClient> = Arc::new(MemStorage::new());
    let journal = Arc::new(Journal::open(storage.clone()).unwrap());
    let engine = Engine::builder()
        .storage(storage)
        .journal(journal.clone())
        .backend(Backend::local_slots("b0", 2))
        .backend(Backend::local_slots("b1", 2))
        .build();
    // the op for i == 3 kills *every* backend while it is itself in
    // flight: its voided attempt fails over into a placement that finds
    // only dead backends
    let backends: Arc<OnceLock<Vec<Arc<Backend>>>> = Arc::new(OnceLock::new());
    let b2 = Arc::clone(&backends);
    let executions: Arc<Mutex<Vec<i64>>> = Arc::new(Mutex::new(Vec::new()));
    let e2 = Arc::clone(&executions);
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        move |ctx| {
            let i = ctx.get_int("i")?;
            e2.lock().unwrap().push(i);
            if i == 3 {
                if let Some(all) = b2.get() {
                    for b in all {
                        b.kill();
                    }
                }
            }
            ctx.set("o", i + 1);
            Ok(())
        },
    ));
    let mut dag = Dag::new("main");
    for i in 0..6 {
        let mut s = Step::new(&format!("t{i}"), "op").key(&format!("t{i}"));
        if i == 0 {
            s = s.param("i", 0i64);
        } else {
            s = s.param_from_step("i", &format!("t{}", i - 1), "o");
        }
        dag = dag.task(s);
    }
    let wf = Workflow::new("chain")
        .container(ContainerTemplate::new("op", op))
        .dag(dag)
        .entrypoint("main");
    backends
        .set(engine.placer().unwrap().backends().to_vec())
        .ok()
        .expect("backends set once");

    let t0 = Instant::now();
    let r = engine.run(&wf).unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "an all-dead placement must fail fast, not hang"
    );
    assert!(!r.succeeded(), "no backend survived; the run cannot succeed");
    assert_eq!(r.run.count_phase(NodePhase::Succeeded), 3, "t0..t2 finished pre-kill");
    let t3 = r.run.nodes().into_iter().find(|n| n.path == "main/t3").unwrap();
    assert!(
        t3.message.contains("dead") && t3.message.contains("b0") && t3.message.contains("b1"),
        "the failure must name the dead backends: {}",
        t3.message
    );
    check::assert_all_drained(&engine, None, Some(&journal));

    // revive and resubmit under the same run id: only t3..t5 re-run
    for b in engine.placer().unwrap().backends() {
        b.revive();
    }
    let before = executions.lock().unwrap().len();
    let r2 = engine.resubmit(&wf, r.run.id).unwrap();
    assert!(r2.succeeded(), "{:?}", r2.error);
    assert_eq!(r2.run.metrics.steps_reused.get(), 3, "t0..t2 reuse their journaled outputs");
    let ran: Vec<i64> = executions.lock().unwrap()[before..].to_vec();
    assert_eq!(ran, vec![3, 4, 5], "exactly the non-succeeded suffix re-runs");
    check::assert_all_drained(&engine, None, Some(&journal));
}

/// Service-level priority: a tenant configured `Priority::High` jumps the
/// ready queue ahead of a flooding normal-priority tenant's backlog, and
/// the service's maintenance tick is a chaos boundary like any other.
#[test]
fn service_dispatches_high_priority_tenant_ahead_of_backlog() {
    let journal = Arc::new(Journal::open(Arc::new(MemStorage::new())).unwrap());
    let engine = Arc::new(
        Engine::builder().backend(Backend::local_slots("box", 1)).journal(journal).build(),
    );
    let config = ServiceConfig {
        max_live_runs: 1,
        default_tenant_quota: 1,
        ..ServiceConfig::default()
    }
    .with_priority("vip", Priority::High);
    let svc = WorkflowService::start(Arc::clone(&engine), config).unwrap();
    let ticked = Arc::new(AtomicBool::new(false));
    let t2 = Arc::clone(&ticked);
    let plan = ChaosPlan::new();
    plan.at(0, ChaosAction::Call(Box::new(move || t2.store(true, Ordering::SeqCst))));
    svc.set_chaos(plan.hook());

    let slow_wf = |name: &str| {
        let op = Arc::new(FnOp::new(Signature::new(), |_| {
            std::thread::sleep(Duration::from_millis(60));
            Ok(())
        }));
        Workflow::new(name)
            .container(ContainerTemplate::new("op", op))
            .steps(Steps::new("main").then(Step::new("s", "op")))
            .entrypoint("main")
    };
    for i in 0..4 {
        svc.submit("batch", slow_wf(&format!("batch-{i}"))).unwrap();
    }
    // let the first batch run start, then the vip arrives
    std::thread::sleep(Duration::from_millis(20));
    let vip_id = svc.submit("vip", slow_wf("vip-0")).unwrap();
    assert!(svc.wait_idle(Duration::from_secs(60)), "service never drained");

    let order = svc.start_order();
    assert_eq!(order.len(), 5);
    let vip_pos = order.iter().position(|(_, id)| *id == vip_id).unwrap();
    assert!(
        vip_pos <= 1,
        "the high-priority tenant started at position {vip_pos}, behind the backlog: {order:?}"
    );
    assert_eq!(svc.metrics().succeeded.get("batch"), 4);
    assert_eq!(svc.metrics().succeeded.get("vip"), 1);
    assert!(ticked.load(Ordering::SeqCst), "chaos boundaries must fire under the service");
    check::assert_all_drained(&engine, None, None);
}
