//! Property tests on coordinator invariants (in-repo mini framework —
//! replay failures with `CHECK_SEED=<seed>`).
//!
//! Invariants:
//! * slices preserve input order in stacked outputs for ANY width/parallelism;
//! * random DAGs execute every task exactly once, respecting dependencies;
//! * the cluster never over-commits under random workflow load;
//! * retry counts never exceed the policy bound;
//! * reuse never re-executes a matched key;
//! * random recursion depths terminate at exactly the requested depth.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use dflow::check::{forall, gen};
use dflow::cluster::{Cluster, Resources};
use dflow::core::{
    CmpOp, ContainerTemplate, Dag, Expr, FnOp, OpError, Operand, ParamType, Signature, Slices,
    Step, StepPolicy, Steps, Value, Workflow,
};
use dflow::engine::Engine;

#[test]
fn prop_slices_preserve_order() {
    forall("slices preserve order", |rng| {
        let width = 1 + rng.below(40) as usize;
        let parallelism = 1 + rng.below(16) as usize;
        let op = Arc::new(FnOp::new(
            Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
            |ctx| {
                // jitter completion order
                std::thread::sleep(std::time::Duration::from_micros(50));
                ctx.set("y", ctx.get_int("x")? * 3);
                Ok(())
            },
        ));
        let xs: Vec<i64> = (0..width as i64).map(|i| i * 7 - 3).collect();
        let wf = Workflow::new("p")
            .container(ContainerTemplate::new("op", op))
            .steps(
                Steps::new("main")
                    .then(
                        Step::new("fan", "op")
                            .param("x", Value::ints(xs.clone()))
                            .slices(Slices::over("x").stack("y").parallelism(parallelism)),
                    )
                    .out_param_from("ys", "fan", "y"),
            )
            .entrypoint("main");
        let r = Engine::local().run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        let ys = r.outputs.params["ys"].as_list().unwrap();
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(ys[i], Value::Int(x * 3), "slot {i}");
        }
    });
}

#[test]
fn prop_random_dags_execute_once_in_order() {
    forall("random dag executes once respecting deps", |rng| {
        let n = 2 + rng.below(10) as usize;
        // random DAG: each task depends on a random subset of earlier tasks
        let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
        for i in 0..n {
            let mut d = Vec::new();
            for j in 0..i {
                if rng.chance(0.4) {
                    d.push(j);
                }
            }
            deps.push(d);
        }
        let log: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let mut dag = Dag::new("main");
        let mut wf = Workflow::new("p");
        for (i, d) in deps.iter().enumerate() {
            let log2 = log.clone();
            let op = Arc::new(FnOp::new(
                Signature::new().out_param("done", ParamType::Int),
                move |ctx| {
                    log2.lock().unwrap().push(i);
                    ctx.set("done", i as i64);
                    Ok(())
                },
            ));
            let mut task = Step::new(&format!("t{i}"), &format!("op{i}"));
            for j in d {
                task = task.depends_on(&format!("t{j}"));
            }
            dag = dag.task(task);
            // one template per task so each op closure is distinct
            wf = wf.container(ContainerTemplate::new(&format!("op{i}"), op));
        }
        let wf = wf.dag(dag).entrypoint("main");
        let r = Engine::local().run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        let order = log.lock().unwrap().clone();
        assert_eq!(order.len(), n, "each task exactly once");
        let pos: std::collections::HashMap<usize, usize> =
            order.iter().enumerate().map(|(p, t)| (*t, p)).collect();
        for (i, d) in deps.iter().enumerate() {
            for j in d {
                assert!(pos[j] < pos[&i], "dep {j} must precede {i}: {order:?}");
            }
        }
    });
}

#[test]
fn prop_cluster_never_overcommits_under_load() {
    forall("cluster never overcommits", |rng| {
        let nodes = 1 + rng.below(4) as usize;
        let cap = 1000 + rng.below(3000);
        let cluster = Arc::new(Cluster::uniform(nodes, Resources::cpu(cap), rng.next_u64()));
        let total = cluster.total_cpu_milli();
        // request must be feasible on a single node: req in [100, cap)
        let req = 100 + rng.below(cap - 100);
        let width = 1 + rng.below(20) as usize;
        let max_free = Arc::new(AtomicUsize::new(0));
        let c2 = cluster.clone();
        let m2 = max_free.clone();
        let op = Arc::new(FnOp::new(
            Signature::new().in_param("i", ParamType::Int),
            move |_| {
                // free CPU may never exceed capacity nor go "negative"
                // (u64 underflow would show as a huge number)
                let free = c2.free_cpu_milli();
                m2.fetch_max(free as usize, Ordering::Relaxed);
                Ok(())
            },
        ));
        let wf = Workflow::new("p")
            .container(ContainerTemplate::new("op", op).resources(Resources::cpu(req)))
            .steps(Steps::new("main").then(
                Step::new("fan", "op")
                    .param("i", Value::ints(0..width as i64))
                    .slices(Slices::over("i")),
            ))
            .entrypoint("main");
        let engine = Engine::builder().cluster(cluster.clone()).build();
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        assert!(max_free.load(Ordering::Relaxed) as u64 <= total);
        assert_eq!(cluster.free_cpu_milli(), total, "all pods released");
        let (bound, released, _) = cluster.stats();
        assert_eq!(bound, released);
        assert_eq!(bound, width as u64);
    });
}

#[test]
fn prop_retry_counts_bounded_by_policy() {
    forall("retries bounded", |rng| {
        let retries = rng.below(5) as u32;
        let attempts = Arc::new(AtomicU32::new(0));
        let a2 = attempts.clone();
        let op = Arc::new(FnOp::new(
            Signature::new(),
            move |_| {
                a2.fetch_add(1, Ordering::SeqCst);
                Err(OpError::Transient("always".into()))
            },
        ));
        let mut policy = StepPolicy::default();
        policy.retries = retries;
        let wf = Workflow::new("p")
            .container(ContainerTemplate::new("op", op))
            .steps(Steps::new("main").then(Step::new("s", "op").policy(policy)))
            .entrypoint("main");
        let r = Engine::local().run(&wf).unwrap();
        assert!(!r.succeeded());
        assert_eq!(attempts.load(Ordering::SeqCst), retries + 1);
        assert_eq!(r.run.metrics.retries.get(), retries as u64);
    });
}

#[test]
fn prop_reuse_never_reexecutes_matched_keys() {
    forall("reuse never re-executes", |rng| {
        let width = 1 + rng.below(12) as usize;
        let reused_subset: Vec<usize> =
            (0..width).filter(|_| rng.chance(0.5)).collect();
        let executed: Arc<Mutex<Vec<i64>>> = Arc::new(Mutex::new(Vec::new()));
        let e2 = executed.clone();
        let op = Arc::new(FnOp::new(
            Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
            move |ctx| {
                let i = ctx.get_int("i")?;
                e2.lock().unwrap().push(i);
                ctx.set("o", i);
                Ok(())
            },
        ));
        let wf = Workflow::new("p")
            .container(ContainerTemplate::new("op", op))
            .steps(Steps::new("main").then(
                Step::new("fan", "op")
                    .param("i", Value::ints(0..width as i64))
                    .slices(Slices::over("i").stack("o"))
                    .key("k-{{item}}"),
            ))
            .entrypoint("main");
        let reuse: Vec<dflow::engine::ReusedStep> = reused_subset
            .iter()
            .map(|i| {
                let mut o = dflow::engine::StepOutputs::default();
                o.params.insert("o".into(), Value::Int(*i as i64));
                dflow::engine::ReusedStep::new(format!("k-{i}"), o)
            })
            .collect();
        let r = Engine::local().run_with_reuse(&wf, reuse).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        let executed = executed.lock().unwrap().clone();
        for i in &reused_subset {
            assert!(
                !executed.contains(&(*i as i64)),
                "slice {i} was reused but also executed"
            );
        }
        assert_eq!(executed.len(), width - reused_subset.len());
        assert_eq!(r.run.metrics.steps_reused.get(), reused_subset.len() as u64);
    });
}

#[test]
fn prop_recursion_terminates_at_requested_depth() {
    forall("recursion depth exact", |rng| {
        let depth = 1 + rng.below(12) as i64;
        let count = Arc::new(AtomicU32::new(0));
        let c2 = count.clone();
        let op = Arc::new(FnOp::new(
            Signature::new().in_param("i", ParamType::Int).out_param("next", ParamType::Int),
            move |ctx| {
                c2.fetch_add(1, Ordering::SeqCst);
                ctx.set("next", ctx.get_int("i")? + 1);
                Ok(())
            },
        ));
        let wf = Workflow::new("p")
            .container(ContainerTemplate::new("inc", op))
            .steps(
                Steps::new("loop")
                    .signature(
                        Signature::new()
                            .in_param("i", ParamType::Int)
                            .in_param("depth", ParamType::Int),
                    )
                    .then(Step::new("body", "inc").param_from_input("i", "i"))
                    .then(
                        Step::new("again", "loop")
                            .param_from_step("i", "body", "next")
                            .param_from_input("depth", "depth")
                            .when(Expr::Cmp {
                                lhs: Operand::StepOutput {
                                    step: "body".into(),
                                    name: "next".into(),
                                },
                                op: CmpOp::Lt,
                                rhs: Operand::Input("depth".into()),
                            }),
                    ),
            )
            .entrypoint("loop")
            .arg("i", 0i64)
            .arg("depth", depth);
        let r = Engine::local().run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        assert_eq!(count.load(Ordering::SeqCst) as i64, depth);
    });
}

#[test]
fn prop_continue_on_ratio_threshold_is_exact() {
    forall("success ratio threshold exact", |rng| {
        let width = 2 + rng.below(12) as usize;
        let fail_every = 2 + rng.below(4) as i64;
        let op = Arc::new(FnOp::new(
            Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
            move |ctx| {
                let i = ctx.get_int("i")?;
                if i % fail_every == 0 {
                    return Err(OpError::Fatal("planned".into()));
                }
                ctx.set("o", i);
                Ok(())
            },
        ));
        let succeeding = (0..width as i64).filter(|i| i % fail_every != 0).count();
        let ratio = succeeding as f64 / width as f64;
        // threshold just below the achieved ratio -> succeed;
        // just above -> fail
        for (delta, expect_ok) in [(-0.01, true), (0.01, false)] {
            let wf = Workflow::new("p")
                .container(ContainerTemplate::new("op", op.clone()))
                .steps(Steps::new("main").then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..width as i64))
                        .slices(Slices::over("i").stack("o").continue_on(
                            dflow::core::ContinueOn::SuccessRatio(ratio + delta),
                        )),
                ))
                .entrypoint("main");
            let r = Engine::local().run(&wf).unwrap();
            assert_eq!(
                r.succeeded(),
                expect_ok,
                "width={width} fail_every={fail_every} ratio={ratio} delta={delta}"
            );
        }
    });
}

#[test]
fn prop_storage_artifact_flow_is_lossless() {
    forall("artifact bytes flow lossless through steps", |rng| {
        let payload: Vec<u8> = (0..rng.below(4096)).map(|_| rng.next_u64() as u8).collect();
        let p2 = payload.clone();
        let writer = Arc::new(FnOp::new(
            Signature::new().out_artifact("data"),
            move |ctx| {
                ctx.write_artifact("data", &p2)?;
                Ok(())
            },
        ));
        let p3 = payload.clone();
        let reader = Arc::new(FnOp::new(
            Signature::new().in_artifact("data").out_param("ok", ParamType::Bool),
            move |ctx| {
                let got = ctx.read_artifact("data")?;
                ctx.set("ok", got == p3);
                Ok(())
            },
        ));
        let wf = Workflow::new("p")
            .container(ContainerTemplate::new("w", writer))
            .container(ContainerTemplate::new("r", reader))
            .steps(
                Steps::new("main")
                    .then(Step::new("w", "w"))
                    .then(Step::new("r", "r").artifact_from_step("data", "w", "data"))
                    .out_param_from("ok", "r", "ok"),
            )
            .entrypoint("main");
        let r = Engine::local().run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);
        assert_eq!(r.outputs.params["ok"], Value::Bool(true));
    });
}

#[test]
fn prop_group_parallel_steps_all_complete() {
    forall("parallel group completes all steps", |rng| {
        let width = 1 + rng.below(8) as usize;
        let names: Vec<String> = (0..width).map(|i| format!("s{}-{}", i, gen::ident(rng))).collect();
        let op = Arc::new(FnOp::new(
            Signature::new().out_param("v", ParamType::Int),
            |ctx| {
                ctx.set("v", 1i64);
                Ok(())
            },
        ));
        let mut steps = Vec::new();
        for n in &names {
            steps.push(Step::new(n, "op"));
        }
        let wf = Workflow::new("p")
            .container(ContainerTemplate::new("op", op))
            .steps(Steps::new("main").then_parallel(steps))
            .entrypoint("main");
        let r = Engine::local().run(&wf).unwrap();
        assert!(r.succeeded());
        assert_eq!(
            r.run.count_phase(dflow::engine::NodePhase::Succeeded) as usize,
            width
        );
    });
}
