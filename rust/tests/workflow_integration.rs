//! Integration: engine + cluster + HPC + storage composition, no artifacts
//! required (runs everywhere).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dflow::cluster::{Cluster, NodeSpec, Resources};
use dflow::core::{
    ContainerTemplate, ContinueOn, Dag, Expr, FnOp, OpError, Operand, ParamType, ShellOp,
    Signature, Slices, Step, StepPolicy, Steps, Value, Workflow,
};
use dflow::engine::{Engine, NodePhase};
use dflow::executor::{DispatcherExecutor, FlakyExecutor};
use dflow::hpc::{HpcScheduler, PartitionSpec};
use dflow::storage::{LocalStorage, ObjectStoreSim};

#[test]
fn shell_pipeline_over_local_storage() {
    // a real /bin/sh two-step pipeline with artifact handoff through a
    // directory-backed store (debug-mode semantics, paper §2.7)
    let dir = std::env::temp_dir().join(format!("dflow-it-{}", dflow::util::next_id()));
    let storage = Arc::new(LocalStorage::new(&dir).unwrap());
    let gen = ShellOp::new(
        Signature::new().in_param("n", ParamType::Str).out_artifact("numbers.txt"),
        r#"seq 1 "$DF_PARAM_N" > outputs/numbers.txt"#,
    );
    let sum = ShellOp::new(
        Signature::new()
            .in_artifact("numbers")
            .out_param("total", ParamType::Str),
        r#"total=$(awk '{s+=$1} END {print s}' numbers); echo "DF_OUT total=$total""#,
    );
    let wf = Workflow::new("shell-pipe")
        .container(ContainerTemplate::new("gen", Arc::new(gen)))
        .container(ContainerTemplate::new("sum", Arc::new(sum)))
        .steps(
            Steps::new("main")
                .then(Step::new("g", "gen").param("n", "10"))
                .then(Step::new("s", "sum").artifact_from_step("numbers", "g", "numbers.txt"))
                .out_param_from("total", "s", "total"),
        )
        .entrypoint("main");
    let engine = Engine::builder().storage(storage).build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.outputs.params["total"], Value::Str("55".into()));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn dispatcher_executor_runs_steps_on_hpc_sim() {
    // §2.6: DispatcherExecutor submits executive steps to a Slurm-like queue
    let sched =
        HpcScheduler::new(vec![PartitionSpec::new("slurm-cpu", 2, Duration::from_secs(10))]);
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            let x = ctx.get_int("x")?;
            ctx.set("y", x + 100);
            Ok(())
        },
    ));
    let wf = Workflow::new("hpc")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("x", Value::ints(0..8))
                        .slices(Slices::over("x").stack("y"))
                        .executor("dispatcher"),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main");
    let engine = Engine::builder()
        .executor("dispatcher", Arc::new(DispatcherExecutor::new(sched.clone(), "slurm-cpu")))
        .build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let ys = r.outputs.params["ys"].as_list().unwrap();
    assert_eq!(ys[7], Value::Int(107));
    let st = sched.partition_stats("slurm-cpu").unwrap();
    assert_eq!(st.submitted, 8);
    assert_eq!(st.completed, 8);
}

#[test]
fn virtual_hpc_nodes_schedule_via_selector() {
    // §2.6 wlm-operator: HPC partitions as labeled virtual nodes
    let cluster = Arc::new(Cluster::new(
        vec![
            NodeSpec::worker("k8s-0", Resources::cpu(4000)),
            NodeSpec::worker("vnode-slurm", Resources::cpu(64_000)).virtual_node("slurm-main"),
        ],
        0,
    ));
    let op = Arc::new(FnOp::new(Signature::new().in_param("i", ParamType::Int), |_| Ok(())));
    let wf = Workflow::new("vnode")
        .container(
            ContainerTemplate::new("hpc-op", op)
                .resources(Resources::cpu(8000)) // only fits the virtual node
                .select_node("dflow/partition", "slurm-main"),
        )
        .steps(Steps::new("main").then(
            Step::new("fan", "hpc-op").param("i", Value::ints(0..4)).slices(Slices::over("i")),
        ))
        .entrypoint("main");
    let engine = Engine::builder().cluster(cluster.clone()).build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let (bound, ..) = cluster.stats();
    assert_eq!(bound, 4);
}

#[test]
fn retries_absorb_flaky_object_store() {
    // storage transient failures surface as OP transient errors and are
    // absorbed by retry policy (§2.4 + §2.8)
    let storage = Arc::new(ObjectStoreSim::new(Duration::ZERO, 0.25, 42));
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_artifact("blob"),
        |ctx| {
            let i = ctx.get_int("i")?;
            ctx.write_artifact("blob", format!("payload-{i}").as_bytes())?;
            Ok(())
        },
    ));
    let mut policy = StepPolicy::default();
    policy.retries = 12;
    let wf = Workflow::new("flaky-store")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(
            Step::new("fan", "op")
                .param("i", Value::ints(0..12))
                .slices(Slices::over("i").stack_artifact("blob"))
                .policy(policy),
        ))
        .entrypoint("main");
    let engine = Engine::builder().storage(storage.clone()).build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert!(storage.failures.load(Ordering::Relaxed) > 0, "no failures were injected");
}

#[test]
fn flaky_cluster_nodes_retried() {
    let cluster = Arc::new(Cluster::new(
        vec![NodeSpec::worker("shaky", Resources::cpu(64_000)).flaky(0.4)],
        7,
    ));
    let op = Arc::new(FnOp::new(Signature::new().in_param("i", ParamType::Int), |_| Ok(())));
    let mut policy = StepPolicy::default();
    policy.retries = 20;
    let wf = Workflow::new("flaky-nodes")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(
            Step::new("fan", "op")
                .param("i", Value::ints(0..20))
                .slices(Slices::over("i"))
                .policy(policy),
        ))
        .entrypoint("main");
    let engine = Engine::builder().cluster(cluster).build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert!(r.run.metrics.retries.get() > 0);
}

#[test]
fn restart_reruns_only_failed_slices() {
    // §2.5 + VSW §3.5: first run fails some shards; resubmission with
    // reuse re-executes only the failed ones
    let executions = Arc::new(AtomicU32::new(0));
    let e2 = executions.clone();
    let sometimes = Arc::new(FnOp::new(
        Signature::new()
            .in_param("i", ParamType::Int)
            .in_param("fail_mask", ParamType::Int)
            .out_param("r", ParamType::Int),
        move |ctx| {
            e2.fetch_add(1, Ordering::SeqCst);
            let i = ctx.get_int("i")?;
            let mask = ctx.get_int("fail_mask")?;
            if mask != 0 && i % 3 == 0 {
                return Err(OpError::Fatal("shard crashed".into()));
            }
            ctx.set("r", i * 2);
            Ok(())
        },
    ));
    let build = |mask: i64| {
        Workflow::new("restartable")
            .container(ContainerTemplate::new("shard", sometimes.clone()))
            .steps(
                Steps::new("main")
                    .then(
                        Step::new("fan", "shard")
                            .param("i", Value::ints(0..9))
                            .param("fail_mask", mask)
                            .slices(
                                Slices::over("i")
                                    .stack("r")
                                    .continue_on(ContinueOn::SuccessRatio(0.5)),
                            )
                            .key("shard-{{item}}"),
                    )
                    .out_param_from("rs", "fan", "r"),
            )
            .entrypoint("main")
    };
    let engine = Engine::local();
    let r1 = engine.run(&build(1)).unwrap();
    assert!(r1.succeeded(), "{:?}", r1.error); // 6/9 ≥ 0.5
    assert_eq!(executions.load(Ordering::SeqCst), 9);
    // collect successes, rerun with failures fixed
    let reuse = r1.run.all_keyed();
    assert_eq!(reuse.len(), 6);
    let r2 = engine.run_with_reuse(&build(0), reuse).unwrap();
    assert!(r2.succeeded());
    // only the 3 failed shards re-executed
    assert_eq!(executions.load(Ordering::SeqCst), 12);
    assert_eq!(r2.run.metrics.steps_reused.get(), 6);
    let rs = r2.outputs.params["rs"].as_list().unwrap();
    assert_eq!(rs[3], Value::Int(6));
    assert_eq!(rs[4], Value::Int(8));
}

#[test]
fn dag_diamond_runs_concurrently() {
    // B and C must overlap in a diamond A -> (B, C) -> D
    let slow = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        move |ctx| {
            std::thread::sleep(Duration::from_millis(80));
            ctx.set("y", ctx.get_int("x")? + 1);
            Ok(())
        },
    ));
    let wf = Workflow::new("diamond")
        .container(ContainerTemplate::new("op", slow))
        .dag(
            Dag::new("main")
                .task(Step::new("a", "op").param("x", 0i64))
                .task(Step::new("b", "op").param_from_step("x", "a", "y"))
                .task(Step::new("c", "op").param_from_step("x", "a", "y"))
                .task(
                    Step::new("d", "op")
                        .param_from_step("x", "b", "y")
                        .depends_on("c"),
                )
                .out_param_from("r", "d", "y"),
        )
        .entrypoint("main");
    let t0 = std::time::Instant::now();
    let r = Engine::local().run(&wf).unwrap();
    let dt = t0.elapsed();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.outputs.params["r"], Value::Int(3));
    // serial would be 4*80ms; diamond should be ~3*80ms
    assert!(dt < Duration::from_millis(320), "{dt:?}");
}

#[test]
fn conditional_branching_workflow() {
    // two branches, one skipped based on a computed value
    let classify = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("big", ParamType::Bool),
        |ctx| {
            let x = ctx.get_int("x")?;
            ctx.set("big", x > 10);
            Ok(())
        },
    ));
    let tagger = |tag: &'static str| {
        Arc::new(FnOp::new(
            Signature::new().out_param("tag", ParamType::Str),
            move |ctx| {
                ctx.set("tag", tag);
                Ok(())
            },
        ))
    };
    let wf = Workflow::new("branch")
        .container(ContainerTemplate::new("classify", classify))
        .container(ContainerTemplate::new("big-path", tagger("big")))
        .container(ContainerTemplate::new("small-path", tagger("small")))
        .steps(
            Steps::new("main")
                .then(Step::new("c", "classify").param("x", 42i64))
                .then_parallel(vec![
                    Step::new("big", "big-path").when(Expr::eq(
                        Operand::StepOutput { step: "c".into(), name: "big".into() },
                        Operand::Const(Value::Bool(true)),
                    )),
                    Step::new("small", "small-path").when(Expr::eq(
                        Operand::StepOutput { step: "c".into(), name: "big".into() },
                        Operand::Const(Value::Bool(false)),
                    )),
                ]),
        )
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.run.count_phase(NodePhase::Skipped), 1);
    let nodes = r.run.nodes();
    let big = nodes.iter().find(|n| n.path.ends_with("/big")).unwrap();
    let small = nodes.iter().find(|n| n.path.ends_with("/small")).unwrap();
    assert_eq!(big.phase, NodePhase::Succeeded);
    assert_eq!(small.phase, NodePhase::Skipped);
}

#[test]
fn nested_super_ops_three_levels() {
    // container inside steps inside dag inside steps — Fig. 2 composability
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            ctx.set("y", ctx.get_int("x")? + 1);
            Ok(())
        },
    ));
    let inner = Steps::new("inner")
        .signature(
            Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        )
        .then(Step::new("s", "op").param_from_input("x", "x"))
        .out_param_from("y", "s", "y");
    let mid = Dag::new("mid")
        .signature(
            Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        )
        .task(Step::new("a", "inner").param_from_input("x", "x"))
        .task(Step::new("b", "inner").param_from_step("x", "a", "y"))
        .out_param_from("y", "b", "y");
    let wf = Workflow::new("nested")
        .container(ContainerTemplate::new("op", op))
        .steps(inner)
        .dag(mid)
        .steps(
            Steps::new("main")
                .then(Step::new("m", "mid").param("x", 10i64))
                .out_param_from("r", "m", "y"),
        )
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.outputs.params["r"], Value::Int(12));
}

#[test]
fn flaky_executor_with_retries_converges() {
    let flaky = Arc::new(FlakyExecutor::new(0.5, 3));
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    let mut policy = StepPolicy::default();
    policy.retries = 30;
    let wf = Workflow::new("flaky-exec")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(
            Step::new("fan", "op")
                .param("i", Value::ints(0..10))
                .slices(Slices::over("i").stack("o"))
                .executor("flaky")
                .policy(policy),
        ))
        .entrypoint("main");
    let engine = Engine::builder().executor("flaky", flaky.clone()).build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert!(flaky.injected.load(Ordering::Relaxed) > 0);
}

#[test]
fn observability_trace_and_status_json() {
    let op = Arc::new(FnOp::new(Signature::new().in_param("i", ParamType::Int), |_| Ok(())));
    let wf = Workflow::new("observed")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(
            Step::new("fan", "op").param("i", Value::ints(0..5)).slices(Slices::over("i")),
        ))
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    assert!(r.succeeded());
    // trace has per-slice running/succeeded events
    assert!(r.run.trace.len() >= 10);
    // status json parses back and contains all nodes
    let j = dflow::jsonx::Json::parse(&r.run.to_json().to_string_pretty()).unwrap();
    let nodes = j.get("nodes").unwrap().as_arr().unwrap();
    assert!(nodes.len() >= 6); // 5 slices + parent
    // timeline export is well-formed
    let tl = r.run.trace.timeline_json();
    assert!(!tl.as_arr().unwrap().is_empty());
}

#[test]
fn workflow_parallelism_cap_respected() {
    use std::sync::atomic::AtomicUsize;
    let live = Arc::new(AtomicUsize::new(0));
    let peak = Arc::new(AtomicUsize::new(0));
    let (l2, p2) = (live.clone(), peak.clone());
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int),
        move |_| {
            let cur = l2.fetch_add(1, Ordering::SeqCst) + 1;
            p2.fetch_max(cur, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(15));
            l2.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        },
    ));
    let wf = Workflow::new("capped")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(
            Step::new("fan", "op").param("i", Value::ints(0..16)).slices(Slices::over("i")),
        ))
        .parallelism(3)
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    assert!(r.succeeded());
    assert!(peak.load(Ordering::SeqCst) <= 3, "peak {}", peak.load(Ordering::SeqCst));
}

#[test]
fn sliced_super_op_steps_template() {
    // §2.3: "Both Python OP and super OP (Steps/DAG) are supported to
    // construct a sliced step" — slice over a Steps template
    let double = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            ctx.set("y", ctx.get_int("x")? * 2);
            Ok(())
        },
    ));
    let addone = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            ctx.set("y", ctx.get_int("x")? + 1);
            Ok(())
        },
    ));
    // the sliced unit is itself a two-step pipeline: y = 2x + 1
    let unit = Steps::new("unit")
        .signature(
            Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        )
        .then(Step::new("d", "double").param_from_input("x", "x"))
        .then(Step::new("a", "addone").param_from_step("x", "d", "y"))
        .out_param_from("y", "a", "y");
    let wf = Workflow::new("sliced-super")
        .container(ContainerTemplate::new("double", double))
        .container(ContainerTemplate::new("addone", addone))
        .steps(unit)
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "unit")
                        .param("x", Value::ints(0..6))
                        .slices(Slices::over("x").stack("y").parallelism(3))
                        .key("unit-{{item}}"),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let ys = r.outputs.params["ys"].as_list().unwrap();
    let expect: Vec<Value> = (0..6).map(|i| Value::Int(i * 2 + 1)).collect();
    assert_eq!(ys, &expect[..]);
    // keyed sliced super-ops are reusable
    assert!(r.run.query_step("unit-3").is_some());
}

#[test]
fn sliced_dag_template() {
    // slice over a DAG super-OP
    let sq = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            let x = ctx.get_int("x")?;
            ctx.set("y", x * x);
            Ok(())
        },
    ));
    let dag = Dag::new("unit")
        .signature(
            Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        )
        .task(Step::new("s", "sq").param_from_input("x", "x"))
        .out_param_from("y", "s", "y");
    let wf = Workflow::new("sliced-dag")
        .container(ContainerTemplate::new("sq", sq))
        .dag(dag)
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "unit")
                        .param("x", Value::ints(0..5))
                        .slices(Slices::over("x").stack("y")),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(
        r.outputs.params["ys"].as_list().unwrap()[4],
        Value::Int(16)
    );
}

#[test]
fn empty_slice_fanout_succeeds_with_empty_stacks() {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            ctx.set("y", ctx.get_int("x")?);
            Ok(())
        },
    ));
    let wf = Workflow::new("empty-fan")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("x", Value::List(vec![]))
                        .slices(Slices::over("x").stack("y")),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    assert_eq!(r.outputs.params["ys"], Value::List(vec![]));
}

#[test]
fn hpc_dispatcher_inside_cluster_virtual_node() {
    // compose §2.6's two paths: a pod bound to a wlm-operator-style virtual
    // node whose execution goes through the Slurm-sim dispatcher
    let sched = HpcScheduler::new(vec![PartitionSpec::new("pbatch", 4, Duration::from_secs(10))]);
    let cluster = Arc::new(Cluster::new(
        vec![NodeSpec::worker("vnode", Resources::cpu(64_000)).virtual_node("pbatch")],
        0,
    ));
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            ctx.set("o", ctx.get_int("i")? + 1);
            Ok(())
        },
    ));
    let wf = Workflow::new("vnode-hpc")
        .container(
            ContainerTemplate::new("op", op)
                .resources(Resources::cpu(8000))
                .select_node("dflow/partition", "pbatch"),
        )
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..6))
                        .slices(Slices::over("i").stack("o"))
                        .executor("slurm"),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main");
    let engine = Engine::builder()
        .cluster(cluster.clone())
        .executor("slurm", Arc::new(DispatcherExecutor::new(sched.clone(), "pbatch")))
        .build();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let st = sched.partition_stats("pbatch").unwrap();
    assert_eq!((st.submitted, st.completed), (6, 6));
    let (bound, ..) = cluster.stats();
    assert_eq!(bound, 6);
}

#[test]
fn async_submit_watch_and_wait() {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            std::thread::sleep(Duration::from_millis(10));
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    let wf = Workflow::new("async")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..8))
                        .slices(Slices::over("i").stack("o").parallelism(2)),
                )
                .out_param_from("os", "fan", "o"),
        )
        .entrypoint("main");
    let engine = Arc::new(Engine::local());
    let submitted = engine.submit(wf).unwrap();
    // watch it live: the run handle is observable before completion
    assert!(!submitted.is_finished() || submitted.run.nodes().is_empty() == false);
    let seen_running = (0..100)
        .any(|_| {
            std::thread::sleep(Duration::from_millis(2));
            submitted.run.count_phase(NodePhase::Running) > 0 || submitted.is_finished()
        });
    assert!(seen_running);
    let result = submitted.wait();
    assert!(result.succeeded(), "{:?}", result.error);
    assert_eq!(result.outputs.params["os"].as_list().unwrap().len(), 8);
}

#[test]
fn debug_dir_dump_end_to_end() {
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_param("o", ParamType::Int),
        |ctx| {
            ctx.set("o", ctx.get_int("i")?);
            Ok(())
        },
    ));
    let wf = Workflow::new("dbg")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(
            Step::new("s", "op").param("i", 1i64).key("the-step"),
        ))
        .entrypoint("main");
    let r = Engine::local().run(&wf).unwrap();
    let root = std::env::temp_dir().join(format!("dflow-dbg-it-{}", dflow::util::next_id()));
    let dir = r.run.dump_debug_dir(&root).unwrap();
    assert_eq!(
        std::fs::read_to_string(dir.join("status")).unwrap().trim(),
        "Succeeded"
    );
    assert!(dir.join("the-step/phase").exists());
    std::fs::remove_dir_all(root).ok();
}
