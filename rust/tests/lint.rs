//! `dflow lint` battery: fixture workflows exercising the stable
//! diagnostic codes, the guarded-step severity downgrade, the seed-app
//! lint-cleanliness guarantee, and the admission soundness property
//! ("zero `DF2xx` diagnostics of any severity ⇒ the run never hits the
//! placer's infeasibility fail-fast").

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use dflow::analysis::{analyze_with, AnalysisContext, Report, ServiceHints, Severity};
use dflow::apps::{apex, deepks, fpop, rid, tesla, vsw};
use dflow::check;
use dflow::cluster::{Cluster, NodeSpec, Resources};
use dflow::core::{
    ContainerTemplate, ContinueOn, Dag, Expr, FnOp, Operand, ParamType, Signature, Slices, Step,
    StepPolicy, Steps, Value, Workflow,
};
use dflow::engine::{Backend, Engine};

fn noop() -> Arc<dyn dflow::core::Op> {
    Arc::new(FnOp::new(Signature::new(), |_| Ok(())))
}

fn leaf(name: &str) -> ContainerTemplate {
    ContainerTemplate::new(name, noop())
}

fn report(wf: &Workflow) -> Report {
    Report::new(dflow::analysis::analyze(wf))
}

/// The heterogeneous cluster the CLI lints against (`demo_cluster` in the
/// `dflow` binary): 4 cpu nodes, 4 gpu nodes labeled `accel=gpu`, one
/// virtual HPC node.
fn demo_like_cluster() -> Arc<Cluster> {
    let mut nodes: Vec<NodeSpec> = (0..4)
        .map(|i| NodeSpec::worker(format!("cpu-{i}"), Resources::new(16_000, 32_000, 0)))
        .collect();
    for i in 0..4 {
        nodes.push(
            NodeSpec::worker(format!("gpu-{i}"), Resources::new(16_000, 32_000, 4))
                .label("accel", "gpu"),
        );
    }
    nodes.push(NodeSpec::worker("vnode-slurm", Resources::cpu(128_000)).virtual_node("slurm-main"));
    Arc::new(Cluster::new(nodes, 0))
}

// -- fixture battery: every pass family fires, ≥8 distinct codes ------------------

#[test]
fn fixtures_exercise_at_least_eight_distinct_codes() {
    fn expect(seen: &mut BTreeSet<&'static str>, r: Report, code: &str) {
        assert!(
            r.diagnostics.iter().any(|d| d.code == code),
            "expected {code}, got: {:?}",
            r.diagnostics
        );
        seen.extend(r.codes());
    }
    let mut seen: BTreeSet<&'static str> = BTreeSet::new();

    // DF001: entrypoint template missing
    expect(&mut seen, report(&Workflow::new("w").entrypoint("main")), "DF001");

    // DF002: step references an unknown template (+ DF011 for the orphan)
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(leaf("orphan"))
                .steps(Steps::new("main").then(Step::new("a", "missing")))
                .entrypoint("main"),
        ),
        "DF002",
    );

    // DF008: DAG task depends on an unknown task
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(leaf("t"))
                .dag(Dag::new("main").task(Step::new("a", "t").depends_on("ghost")))
                .entrypoint("main"),
        ),
        "DF008",
    );

    // DF012: a task depending on itself
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(leaf("t"))
                .dag(Dag::new("main").task(Step::new("a", "t").depends_on("a")))
                .entrypoint("main"),
        ),
        "DF012",
    );

    // DF010: duplicate step names in one template
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(leaf("t"))
                .steps(
                    Steps::new("main")
                        .then(Step::new("a", "t"))
                        .then(Step::new("a", "t")),
                )
                .entrypoint("main"),
        ),
        "DF010",
    );

    // DF101: consuming an output the producer's template never declares
    let sink = ContainerTemplate::new(
        "sink",
        Arc::new(FnOp::new(Signature::new().in_param("x", ParamType::Int), |_| Ok(()))),
    );
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(leaf("t"))
                .container(sink.clone())
                .steps(
                    Steps::new("main")
                        .then(Step::new("a", "t"))
                        .then(Step::new("b", "sink").param_from_step("x", "a", "nope")),
                )
                .entrypoint("main"),
        ),
        "DF101",
    );

    // DF102: produced output artifact neither consumed nor exported
    let producer = ContainerTemplate::new(
        "producer",
        Arc::new(FnOp::new(Signature::new().out_artifact("blob"), |_| Ok(()))),
    );
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(producer.clone())
                .steps(Steps::new("main").then(Step::new("a", "producer")))
                .entrypoint("main"),
        ),
        "DF102",
    );

    // ...but a reuse key exempts the producer (addressable via query_step)
    let keyed = report(
        &Workflow::new("w")
            .container(producer)
            .steps(Steps::new("main").then(Step::new("a", "producer").key("a")))
            .entrypoint("main"),
    );
    assert!(
        !keyed.diagnostics.iter().any(|d| d.code == "DF102"),
        "keyed step must be DF102-exempt: {:?}",
        keyed.diagnostics
    );

    // DF103 / DF104: sliced parameter is not a list / widths disagree
    let fan = ContainerTemplate::new(
        "fan",
        Arc::new(FnOp::new(
            Signature::new()
                .in_param("xs", ParamType::List)
                .in_param("ys", ParamType::List),
            |_| Ok(()),
        )),
    );
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(fan.clone())
                .steps(Steps::new("main").then(
                    Step::new("a", "fan")
                        .param("xs", 5i64)
                        .param("ys", Value::ints(0..2))
                        .slices(Slices::over("xs")),
                ))
                .entrypoint("main"),
        ),
        "DF103",
    );
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(fan)
                .steps(Steps::new("main").then(
                    Step::new("a", "fan")
                        .param("xs", Value::ints(0..3))
                        .param("ys", Value::ints(0..5))
                        .slices(Slices::over("xs").and("ys")),
                ))
                .entrypoint("main"),
        ),
        "DF104",
    );

    // DF105: template output sourced from an output nobody produces
    expect(
        &mut seen,
        report(
            &Workflow::new("w")
                .container(leaf("t"))
                .steps(
                    Steps::new("main")
                        .then(Step::new("a", "t"))
                        .out_param_from("r", "a", "nope"),
                )
                .entrypoint("main"),
        ),
        "DF105",
    );

    // DF301 / DF302 / DF304: hopeless policies
    let mut zero_timeout = StepPolicy::default();
    zero_timeout.timeout = Some(Duration::from_millis(0));
    zero_timeout.retries = 3;
    let mut storm = StepPolicy::default();
    storm.retries = 12; // default backoff is zero
    let fanout = ContainerTemplate::new(
        "fan",
        Arc::new(FnOp::new(Signature::new().in_param("xs", ParamType::List), |_| Ok(()))),
    );
    let r = report(
        &Workflow::new("w")
            .container(leaf("t"))
            .container(fanout)
            .steps(
                Steps::new("main")
                    .then(Step::new("a", "t").policy(zero_timeout))
                    .then(Step::new("b", "t").policy(storm))
                    .then(
                        Step::new("c", "fan")
                            .param("xs", Value::ints(0..2))
                            .slices(
                                Slices::over("xs").continue_on(ContinueOn::SuccessNumber(5)),
                            ),
                    ),
            )
            .entrypoint("main"),
    );
    for code in ["DF301", "DF302", "DF304"] {
        assert!(
            r.diagnostics.iter().any(|d| d.code == code),
            "expected {code}: {:?}",
            r.diagnostics
        );
    }
    seen.extend(r.codes());

    // DF201 / DF203 / DF205: routing against a backend registry
    let engine = Engine::builder().backend(Backend::local("alpha")).build();
    let wf = Workflow::new("w")
        .container(leaf("t"))
        .steps(
            Steps::new("main")
                .then(Step::new("a", "t").on_backend("ghost"))
                .then(Step::new("b", "t").executor("local").on_backend("alpha"))
                .then(Step::new("c", "t").executor("phantom")),
        )
        .entrypoint("main");
    let r = engine.lint(&wf);
    for code in ["DF201", "DF203", "DF205"] {
        assert!(
            r.diagnostics.iter().any(|d| d.code == code),
            "expected {code}: {:?}",
            r.diagnostics
        );
    }
    seen.extend(r.codes());

    // DF204: a selector but no placement layer at all
    let r = Engine::local().lint(
        &Workflow::new("w")
            .container(leaf("t"))
            .steps(Steps::new("main").then(Step::new("a", "t").backend_where("tier", "gpu")))
            .entrypoint("main"),
    );
    expect(&mut seen, r, "DF204");

    // DF202: request fits no node of the engine cluster
    let tiny = Arc::new(Cluster::uniform(1, Resources::cpu(1000), 0));
    let engine = Engine::builder().cluster(tiny).build();
    let r = engine.lint(
        &Workflow::new("w")
            .container(leaf("huge").resources(Resources::cpu(64_000)))
            .steps(Steps::new("main").then(Step::new("a", "huge")))
            .entrypoint("main"),
    );
    expect(&mut seen, r, "DF202");

    assert!(
        seen.len() >= 8,
        "fixture battery must exercise >= 8 distinct codes, got {}: {seen:?}",
        seen.len()
    );
}

#[test]
fn capacity_codes_fire_against_slot_backends() {
    // DF303: fan-out wider than the total slots of the matching backends
    let engine = Engine::builder().backend(Backend::local_slots("small", 2)).build();
    let fan = ContainerTemplate::new(
        "fan",
        Arc::new(FnOp::new(Signature::new().in_param("xs", ParamType::List), |_| Ok(()))),
    );
    let wide = Workflow::new("w")
        .container(fan)
        .steps(Steps::new("main").then(
            Step::new("a", "fan").param("xs", Value::ints(0..8)).slices(Slices::over("xs")),
        ))
        .entrypoint("main");
    let r = engine.lint(&wide);
    assert!(
        r.diagnostics.iter().any(|d| d.code == "DF303" && d.severity == Severity::Warning),
        "expected DF303 warning: {:?}",
        r.diagnostics
    );

    // DF305: one run fits, max_live_runs of them overcommit
    let narrow = Workflow::new("w")
        .container(ContainerTemplate::new(
            "fan",
            Arc::new(FnOp::new(Signature::new().in_param("xs", ParamType::List), |_| Ok(()))),
        ))
        .steps(Steps::new("main").then(
            Step::new("a", "fan").param("xs", Value::ints(0..2)).slices(Slices::over("xs")),
        ))
        .entrypoint("main");
    let mut ctx = engine.analysis_context();
    ctx.service = Some(ServiceHints { max_live_runs: 4 });
    let r = Report::new(analyze_with(&narrow, &ctx));
    assert!(
        r.diagnostics.iter().any(|d| d.code == "DF305" && d.severity == Severity::Warning),
        "expected DF305 warning: {:?}",
        r.diagnostics
    );
    // and without the service hint the same workflow is clean
    let r = engine.lint(&narrow);
    assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
}

#[test]
fn guarded_steps_downgrade_placement_findings_to_warnings() {
    let engine = Engine::builder().backend(Backend::local("alpha")).build();
    let never = Expr::eq(Operand::Const(Value::Int(1)), Operand::Const(Value::Int(2)));
    let mut soft = StepPolicy::default();
    soft.continue_on_failed = true;
    let wf = Workflow::new("w")
        .container(leaf("t"))
        .steps(
            Steps::new("main")
                .then(Step::new("a", "t").on_backend("ghost").when(never))
                .then(Step::new("b", "t").on_backend("ghost").key("b"))
                .then(Step::new("c", "t").on_backend("ghost").policy(soft)),
        )
        .entrypoint("main");
    let r = engine.lint(&wf);
    let df201: Vec<_> = r.diagnostics.iter().filter(|d| d.code == "DF201").collect();
    assert_eq!(df201.len(), 3, "{:?}", r.diagnostics);
    assert!(df201.iter().all(|d| d.severity == Severity::Warning), "{df201:?}");
    assert!(!r.has_errors());
}

// -- every seed app lints clean under the CLI's context ---------------------------

#[test]
fn seed_apps_lint_clean_against_demo_cluster() {
    let scales = [0.85, 0.9, 0.95, 1.0, 1.05, 1.1, 1.15];
    let apps: Vec<(&str, Workflow)> = vec![
        ("fpop-eos", fpop::eos_workflow(7, &scales, 2)),
        ("apex-relaxation", apex::relaxation_workflow(1)),
        (
            "apex-property",
            apex::property_workflow(&scales)
                .input_artifact("relaxed", dflow::core::ArtifactRef::new("inputs/relaxed")),
        ),
        ("apex-joint", apex::joint_workflow(1, &scales)),
        ("rid", rid::workflow(&rid::RidConfig::default(), 5)),
        ("deepks", deepks::workflow(&deepks::DeepksConfig::default())),
        ("vsw", vsw::workflow(&vsw::VswConfig::default(), 99)),
        ("tesla", tesla::workflow(&tesla::TeslaConfig::default(), 1)),
    ];
    let cluster = demo_like_cluster();
    let ctx = AnalysisContext {
        placer: None,
        cluster: Some(&cluster),
        executors: Some(vec!["local".to_string()]),
        service: Some(ServiceHints { max_live_runs: 4 }),
    };
    for (name, wf) in &apps {
        let r = Report::new(analyze_with(wf, &ctx));
        assert!(
            r.diagnostics.is_empty(),
            "app '{name}' must lint clean, got:\n{}",
            r.diagnostics
                .iter()
                .map(|d| format!("  {} ({})", d.render(), d.node))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}

// -- soundness: zero DF2xx (any severity) ⇒ no placer fail-fast at runtime --------

#[test]
fn zero_df2xx_implies_no_placer_failfast() {
    let label_pool = [("tier", "cloud"), ("tier", "hpc"), ("accel", "gpu")];
    check::forall_cases("lint placement soundness", 48, |rng| {
        // random backend registry: 1-3 backends, random caps + labels
        let n_backends = 1 + rng.below(3) as usize;
        let mut builder = Engine::builder();
        let mut names: Vec<String> = Vec::new();
        for i in 0..n_backends {
            let name = format!("b{i}");
            let mut b = if rng.below(2) == 0 {
                Backend::local(&name)
            } else {
                Backend::local_slots(&name, 1 + rng.below(3) as usize)
            };
            if rng.below(2) == 0 {
                let (k, v) = label_pool[rng.below(label_pool.len() as u64) as usize];
                b = b.label(k, v);
            }
            names.push(name);
            builder = builder.backend(b);
        }
        let engine = builder.build();

        // random workflow: 1-4 trivial steps with random routing, some of
        // which may name backends that don't exist or labels nobody carries
        let mut steps = Steps::new("main");
        let n_steps = 1 + rng.below(4) as usize;
        for s in 0..n_steps {
            let mut st = Step::new(&format!("s{s}"), "op");
            match rng.below(4) {
                0 => {} // any backend
                1 => st = st.on_backend("ghost"),
                2 => {
                    let (k, v) = label_pool[rng.below(label_pool.len() as u64) as usize];
                    st = st.backend_where(k, v);
                }
                _ => {
                    let name = &names[rng.below(names.len() as u64) as usize];
                    st = st.on_backend(name);
                }
            }
            steps = steps.then(st);
        }
        let wf = Workflow::new("prop")
            .container(leaf("op"))
            .steps(steps)
            .entrypoint("main");

        let has_df2xx =
            engine.lint(&wf).diagnostics.iter().any(|d| d.code.starts_with("DF2"));
        match engine.run(&wf) {
            Ok(r) => {
                // admitted: a clean DF2xx bill means the placer can satisfy
                // every step, so nothing may fail
                if !has_df2xx {
                    assert!(r.succeeded(), "clean lint but run failed: {:?}", r.error);
                }
            }
            Err(e) => {
                // rejected at admission: only a DF2xx error can explain it
                // (the workflow is structurally valid by construction)
                assert!(has_df2xx, "rejected without any DF2xx diagnostic: {e}");
            }
        }
    });
}

// -- admission surfaces: Engine::run rejects, messages carry the code -------------

#[test]
fn admission_rejection_names_code_step_and_backends() {
    let engine = Engine::builder()
        .backend(Backend::local("alpha"))
        .backend(Backend::local("beta"))
        .build();
    let wf = Workflow::new("doomed")
        .container(leaf("t"))
        .steps(Steps::new("main").then(Step::new("nowhere", "t").on_backend("quantum")))
        .entrypoint("main");
    let msg = engine.run(&wf).unwrap_err();
    for needle in ["DF201", "main/nowhere", "quantum", "alpha", "beta"] {
        assert!(msg.contains(needle), "missing '{needle}' in: {msg}");
    }
}
