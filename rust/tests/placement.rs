//! Multi-backend placement battery (ISSUE 2):
//! * property tests (`check::forall_cases`, 128 cases): randomly generated
//!   workflows over random backend sets never over-commit any backend's
//!   capacity, and every step lands on a backend matching its selector;
//! * fault injection: a flaky backend failing mid-run must not strand its
//!   per-backend permit; an infeasible request must fail the step fast with
//!   the backend name in the error — without consuming a pool worker;
//! * integration: one run demonstrably splits across three registered
//!   backends (k8s-sim + HPC partition + local slots), capacity-aware.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dflow::bench_util::ConcurrencyProbe;
use dflow::check;
use dflow::cluster::{Cluster, Resources};
use dflow::core::{
    BackendSelector, ContainerTemplate, Dag, FnOp, ParamType, Signature, Slices, Step,
    StepPolicy, Steps, Value, Workflow,
};
use dflow::engine::{Backend, BackendCapacity, Engine, NodePhase, PlaceRequest};
use dflow::executor::{DispatcherExecutor, FlakyExecutor, LocalExecutor, ProbeExecutor};
use dflow::hpc::{HpcScheduler, PartitionSpec};
use dflow::metrics::EventKind;

#[test]
fn random_workflows_never_overcommit_and_match_selectors() {
    check::forall_cases("placement: capacity + selector invariants", 128, |rng| {
        // N backends with random small capacities (slot-counted or
        // cluster-backed), each in a random label group
        let nb = 2 + rng.below(4) as usize; // 2..=5
        let groups = ["x", "y"];
        let mut backends = Vec::new();
        let mut probes = Vec::new();
        let mut caps = Vec::new();
        let mut labels = Vec::new();
        for i in 0..nb {
            let cap = 1 + rng.below(3) as usize; // 1..=3
            let group = *check::gen::choose(rng, &groups);
            let probe = ConcurrencyProbe::new();
            let exec = Arc::new(ProbeExecutor::new(Arc::new(LocalExecutor), probe.clone()));
            let capacity = if rng.chance(0.3) {
                // cluster-backed: `cap` nodes, each fitting exactly one
                // cpu(1000) pod, so the concurrency cap is `cap` too
                BackendCapacity::Cluster(Arc::new(Cluster::uniform(
                    cap,
                    Resources::cpu(1000),
                    rng.next_u64(),
                )))
            } else {
                BackendCapacity::Slots(cap)
            };
            backends
                .push(Backend::custom(format!("b{i}"), exec, capacity).label("group", group));
            probes.push(probe);
            caps.push(cap);
            labels.push(group);
        }
        let mut builder = Engine::builder().parallelism(8);
        for b in backends {
            builder = builder.backend(b);
        }
        let engine = builder.build();

        // the OP dawdles briefly so leases overlap and peaks are meaningful
        let op = Arc::new(FnOp::new(
            Signature::new().out_param("v", ParamType::Int),
            |ctx| {
                std::thread::sleep(Duration::from_micros(500));
                ctx.set("v", 1i64);
                Ok(())
            },
        ));
        let ns = 4 + rng.below(12) as usize; // 4..=15 independent tasks
        let mut dag = Dag::new("main");
        // (step path, pinned backend name, required label group)
        let mut sels: Vec<(String, Option<String>, Option<&str>)> = Vec::new();
        for j in 0..ns {
            let mut step = Step::new(&format!("s{j}"), "op");
            let (pin_name, pin_label) = match rng.below(3) {
                0 => (None, None), // any backend
                1 => (Some(format!("b{}", rng.below(nb as u64))), None),
                _ => (None, Some(labels[rng.below(nb as u64) as usize])),
            };
            if let Some(n) = &pin_name {
                step = step.on_backend(n);
            }
            if let Some(g) = pin_label {
                step = step.backend_where("group", g);
            }
            sels.push((format!("main/s{j}"), pin_name, pin_label));
            dag = dag.task(step);
        }
        let wf = Workflow::new("w")
            .container(ContainerTemplate::new("op", op).resources(Resources::cpu(1000)))
            .dag(dag)
            .entrypoint("main");
        let r = engine.run(&wf).unwrap();
        assert!(r.succeeded(), "{:?}", r.error);

        // capacity invariant: no backend ever ran more OPs concurrently
        // than its capacity
        for (i, probe) in probes.iter().enumerate() {
            assert!(
                probe.peak() <= caps[i],
                "backend b{i} over-committed: peak {} > capacity {}",
                probe.peak(),
                caps[i]
            );
        }
        // accounting drains: nothing stranded anywhere in the stack
        check::assert_all_drained(&engine, None, None);
        // every step placed exactly once, on a backend matching its selector
        let placed: BTreeMap<String, String> = r
            .run
            .trace
            .snapshot()
            .into_iter()
            .filter(|e| e.kind == EventKind::StepPlaced)
            .map(|e| (e.step, e.detail))
            .collect();
        assert_eq!(placed.len(), ns, "every step must have a StepPlaced event");
        let total: u64 = r.run.placements().values().sum();
        assert_eq!(total as usize, ns);
        for (path, pin_name, pin_label) in &sels {
            let b = placed.get(path).unwrap_or_else(|| panic!("step {path} never placed"));
            if let Some(n) = pin_name {
                assert_eq!(b, n, "{path} pinned to {n} but ran on {b}");
            }
            if let Some(g) = pin_label {
                let idx: usize = b.trim_start_matches('b').parse().unwrap();
                assert_eq!(
                    labels[idx], *g,
                    "{path} required group {g} but {b} is in group {}",
                    labels[idx]
                );
            }
        }
    });
}

#[test]
fn flaky_backend_failure_releases_per_backend_permit() {
    // FlakyExecutor(rate = 1.0) fails every attempt transiently; with
    // retries the step still fails — and every one of the failed attempts
    // must hand its backend lease back (nothing stranded mid-run)
    let flaky = Arc::new(FlakyExecutor::new(1.0, 3));
    let engine = Engine::builder()
        .backend(Backend::custom(
            "flaky-remote",
            flaky.clone(),
            BackendCapacity::Slots(1),
        ))
        .build();
    let op = Arc::new(FnOp::new(Signature::new(), |_| Ok(())));
    let mut policy = StepPolicy::default();
    policy.retries = 2;
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(Step::new("s", "op").policy(policy)))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(!r.succeeded());
    assert_eq!(flaky.attempts.load(std::sync::atomic::Ordering::Relaxed), 3);
    let b = engine.placer().unwrap().backend("flaky-remote").unwrap();
    assert_eq!(b.inflight(), 0, "failed attempts stranded a lease");
    assert_eq!(b.placed_total(), 3, "each retry re-places");
    assert_eq!(b.peak_inflight(), 1, "slots(1) backend never held two leases");

    // capacity is genuinely reusable afterwards: a second run gets all its
    // attempts placed again on the same 1-slot backend
    let r2 = engine.run(&wf).unwrap();
    assert!(!r2.succeeded());
    assert_eq!(flaky.attempts.load(std::sync::atomic::Ordering::Relaxed), 6);
    check::assert_all_drained(&engine, None, None);
}

#[test]
fn infeasible_step_fails_fast_with_backend_name_without_a_worker() {
    // parallelism-1 engine: if the infeasible task blocked in a capacity
    // wait it would starve the single pool worker forever; instead the
    // ready queue fails it without an attempt (no scheduling permit, no
    // capacity wait) and the 20 feasible tasks all run
    let cluster = Arc::new(Cluster::uniform(1, Resources::cpu(1000), 0));
    let engine = Engine::builder()
        .backend(Backend::cluster("small-k8s", cluster))
        .parallelism(1)
        .build();
    let op = Arc::new(FnOp::new(Signature::new(), |_| Ok(())));
    let big_op = Arc::new(FnOp::new(Signature::new(), |_| Ok(())));
    let mut cof = StepPolicy::default();
    cof.continue_on_failed = true;
    let mut dag = Dag::new("main").task(Step::new("bad", "big").policy(cof));
    for i in 0..20 {
        dag = dag.task(Step::new(&format!("ok{i}"), "op"));
    }
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("op", op))
        .container(ContainerTemplate::new("big", big_op).resources(Resources::cpu(64_000)))
        .dag(dag)
        .entrypoint("main");
    let t0 = Instant::now();
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error); // bad is continue_on_failed
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "infeasible task must fail fast, not block: {:?}",
        t0.elapsed()
    );
    assert_eq!(r.run.count_phase(NodePhase::Failed), 1);
    assert_eq!(r.run.count_phase(NodePhase::Succeeded), 20);
    let bad = r.run.nodes().into_iter().find(|n| n.path == "main/bad").unwrap();
    assert!(
        bad.message.contains("small-k8s"),
        "error must name the backend: {}",
        bad.message
    );
    // the infeasible task never entered the attempt path (no scheduling
    // permit): dispatch latency is observed exactly once per *real* attempt
    assert_eq!(r.run.metrics.dispatch.count(), 20);
    assert_eq!(r.run.metrics.placement_rejected.get(), 1);
    assert_eq!(r.run.metrics.placements.get(), 20);
    assert!(r
        .run
        .trace
        .snapshot()
        .iter()
        .all(|e| !(e.kind == EventKind::StepPlaced && e.step == "main/bad")));
}

#[test]
fn selector_matching_nothing_is_rejected_at_admission() {
    // a selector no registered backend can ever satisfy is now a static
    // (DF201) admission error — the run is refused before a single node
    // is scheduled, and the message still names the selector and the
    // known backends
    let engine = Engine::builder()
        .backend(Backend::local("alpha"))
        .backend(Backend::local("beta"))
        .build();
    let op = Arc::new(FnOp::new(Signature::new(), |_| Ok(())));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(Step::new("s", "op").backend_where("tier", "gpu")))
        .entrypoint("main");
    let msg = engine.run(&wf).unwrap_err();
    assert!(msg.contains("DF201"), "{msg}");
    assert!(msg.contains("main/s"), "{msg}");
    assert!(msg.contains("tier=gpu"), "{msg}");
    assert!(msg.contains("alpha") && msg.contains("beta"), "{msg}");

    // the same workflow behind a `when` guard only warns — the engine
    // admits it, and the guarded step simply never runs its leaf
    let guarded = Workflow::new("w2")
        .container(ContainerTemplate::new(
            "op2",
            Arc::new(FnOp::new(Signature::new(), |_| Ok(()))),
        ))
        .steps(
            Steps::new("main").then(
                Step::new("s", "op2").backend_where("tier", "gpu").when(
                    dflow::core::Expr::eq(
                        dflow::core::Operand::Const(Value::Int(1)),
                        dflow::core::Operand::Const(Value::Int(2)),
                    ),
                ),
            ),
        )
        .entrypoint("main");
    let r = engine.run(&guarded).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
}

#[test]
fn placement_routes_around_full_backends() {
    // hold backend a's only slot externally; an unpinned step must land on b
    let engine = Engine::builder()
        .backend(Backend::local_slots("a", 1))
        .backend(Backend::local_slots("b", 1))
        .build();
    let placer = engine.placer().unwrap();
    let hold = placer
        .try_place(&PlaceRequest {
            selector: BackendSelector::named("a"),
            ..Default::default()
        })
        .unwrap()
        .unwrap();
    assert_eq!(hold.backend_name(), "a");
    let op = Arc::new(FnOp::new(Signature::new(), |_| Ok(())));
    let wf = Workflow::new("w")
        .container(ContainerTemplate::new("op", op))
        .steps(Steps::new("main").then(Step::new("s", "op")))
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let expect: BTreeMap<String, u64> = [("b".to_string(), 1u64)].into_iter().collect();
    assert_eq!(r.run.placements(), expect);
    drop(hold);
}

#[test]
fn one_run_splits_across_three_backends_capacity_aware() {
    // acceptance: a single workflow demonstrably executes steps on ≥ 3
    // registered backends in one run, each within its own capacity
    let cluster = Arc::new(Cluster::uniform(2, Resources::cpu(2000), 0));
    let slurm =
        HpcScheduler::new(vec![PartitionSpec::new("batch", 3, Duration::from_secs(60))]);
    let (pk, ph, pl) =
        (ConcurrencyProbe::new(), ConcurrencyProbe::new(), ConcurrencyProbe::new());
    let engine = Engine::builder()
        .backend(Backend::custom(
            "k8s",
            Arc::new(ProbeExecutor::new(Arc::new(LocalExecutor), pk.clone())),
            BackendCapacity::Cluster(cluster.clone()),
        ))
        .backend(Backend::custom(
            "hpc",
            Arc::new(ProbeExecutor::new(
                Arc::new(DispatcherExecutor::new(slurm.clone(), "batch")),
                ph.clone(),
            )),
            BackendCapacity::Partition { sched: slurm.clone(), partition: "batch".into() },
        ))
        .backend(Backend::custom(
            "edge",
            Arc::new(ProbeExecutor::new(Arc::new(LocalExecutor), pl.clone())),
            BackendCapacity::Slots(2),
        ))
        .parallelism(16)
        .build();
    let sq = Arc::new(FnOp::new(
        Signature::new().in_param("x", ParamType::Int).out_param("y", ParamType::Int),
        |ctx| {
            let x = ctx.get_int("x")?;
            std::thread::sleep(Duration::from_millis(2));
            ctx.set("y", x * x);
            Ok(())
        },
    ));
    let wf = Workflow::new("spread")
        // cpu(2000) fills one cluster node per pod → k8s concurrency cap 2
        .container(ContainerTemplate::new("sq", sq).resources(Resources::cpu(2000)))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "sq")
                        .param("x", Value::ints(0..30))
                        .slices(Slices::over("x").stack("y").parallelism(30)),
                )
                .out_param_from("ys", "fan", "y"),
        )
        .entrypoint("main");
    let r = engine.run(&wf).unwrap();
    assert!(r.succeeded(), "{:?}", r.error);
    let ys = r.outputs.params["ys"].as_list().unwrap();
    assert_eq!(ys.len(), 30);
    assert_eq!(ys[29], Value::Int(29 * 29));

    let split = r.run.placements();
    assert_eq!(split.values().sum::<u64>(), 30);
    for name in ["k8s", "hpc", "edge"] {
        assert!(
            split.get(name).copied().unwrap_or(0) > 0,
            "backend {name} got no slices: {split:?}"
        );
    }
    assert!(pk.peak() <= 2, "k8s peak {} > 2 nodes", pk.peak());
    assert!(ph.peak() <= 3, "hpc peak {} > 3 slots", ph.peak());
    assert!(pl.peak() <= 2, "edge peak {} > 2 slots", pl.peak());
    let (_, _, peak_pods) = cluster.stats();
    assert!(peak_pods <= 2);
    assert_eq!(slurm.inflight(), 0);
    // pod bind/release balance, partition drain and lease accounting are
    // all covered by the shared audit
    check::assert_all_drained(&engine, None, None);
}
