//! Storage hardening battery (§2.8): one contract exercised against every
//! client — MemStorage, LocalStorage, ObjectStoreSim, and CAS-wrapped
//! variants — plus the dedup/zero-copy/gc properties of the
//! content-addressed layer and the end-to-end guarantee the engine builds
//! on it: forwarding an unchanged artifact between steps moves **zero**
//! data bytes.
//!
//! Run via `make test-storage` (part of `make ci`).

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use dflow::check;
use dflow::core::{
    ContainerTemplate, FnOp, OpCtx, ParamType, Signature, Slices, Step, Steps, Value, Workflow,
};
use dflow::engine::Engine;
use dflow::storage::{
    pack_dir, unpack_dir, CasStore, LocalStorage, MemStorage, ObjectStoreSim, StorageClient,
    StorageError,
};
use dflow::util::{md5_hex, next_id, Rng};

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("dflow-sc-{}-{}", name, next_id()));
    fs::create_dir_all(&p).unwrap();
    p
}

/// Every client the battery runs against: the three base stores and CAS
/// layered over two of them.
fn clients(tag: &str) -> Vec<(String, Arc<dyn StorageClient>)> {
    vec![
        ("mem".to_string(), Arc::new(MemStorage::new()) as Arc<dyn StorageClient>),
        (
            "local".to_string(),
            Arc::new(LocalStorage::new(tmp(&format!("{tag}-local"))).unwrap()),
        ),
        ("sim".to_string(), Arc::new(ObjectStoreSim::new(Duration::ZERO, 0.0, 1))),
        (
            "cas-mem".to_string(),
            Arc::new(CasStore::new(Arc::new(MemStorage::new()))),
        ),
        (
            "cas-local".to_string(),
            Arc::new(CasStore::new(Arc::new(
                LocalStorage::new(tmp(&format!("{tag}-cas-local"))).unwrap(),
            ))),
        ),
    ]
}

// -- contract ------------------------------------------------------------------

#[test]
fn contract_roundtrip_list_copy_md5_delete() {
    for (name, c) in clients("contract") {
        c.upload("a/x", b"hello").unwrap();
        c.upload("a/y", b"world").unwrap();
        assert_eq!(c.download("a/x").unwrap(), b"hello", "{name}");
        assert_eq!(
            c.list("a/").unwrap(),
            vec!["a/x".to_string(), "a/y".to_string()],
            "{name}"
        );
        c.copy("a/x", "b/x").unwrap();
        assert_eq!(c.download("b/x").unwrap(), b"hello", "{name}");
        assert_eq!(c.get_md5("a/x").unwrap(), md5_hex(b"hello"), "{name}");
        assert!(matches!(c.download("nope"), Err(StorageError::NotFound(_))), "{name}");
        assert!(matches!(c.copy("nope", "d"), Err(StorageError::NotFound(_))), "{name}");
        c.delete("b/x").unwrap();
        assert!(matches!(c.download("b/x"), Err(StorageError::NotFound(_))), "{name}");
        assert!(matches!(c.delete("b/x"), Err(StorageError::NotFound(_))), "{name}");
    }
}

#[test]
fn contract_key_escapes_rejected_everywhere() {
    for (name, c) in clients("escape") {
        c.upload("ok/x", b"v").unwrap();
        for bad in ["../evil", "/etc/passwd", "a/../../b", "a//b", "a/./b", "", "a\\b", ".."] {
            assert!(
                matches!(c.upload(bad, b"x"), Err(StorageError::Fatal(_))),
                "{name}: upload('{bad}') must be rejected"
            );
            assert!(
                matches!(c.download(bad), Err(StorageError::Fatal(_))),
                "{name}: download('{bad}') must be rejected"
            );
            assert!(
                matches!(c.copy(bad, "ok/y"), Err(StorageError::Fatal(_))),
                "{name}: copy src '{bad}' must be rejected"
            );
            assert!(
                matches!(c.copy("ok/x", bad), Err(StorageError::Fatal(_))),
                "{name}: copy dst '{bad}' must be rejected"
            );
            assert!(
                matches!(c.delete(bad), Err(StorageError::Fatal(_))),
                "{name}: delete('{bad}') must be rejected"
            );
            assert!(
                matches!(c.get_md5(bad), Err(StorageError::Fatal(_))),
                "{name}: get_md5('{bad}') must be rejected"
            );
        }
        assert!(
            matches!(c.list("../x"), Err(StorageError::Fatal(_))),
            "{name}: list('../x') must be rejected"
        );
    }
}

#[test]
fn local_escaping_keys_never_write_outside_root() {
    let parent = tmp("no-escape");
    let store_root = parent.join("store");
    let s = LocalStorage::new(&store_root).unwrap();
    let evil_target = parent.join("evil");
    assert!(s.upload("../evil", b"boom").is_err());
    assert!(s.upload("x/../../evil", b"boom").is_err());
    assert!(!evil_target.exists(), "key escape wrote outside the store root");
    let abs = std::env::temp_dir().join(format!("dflow-evil-{}", next_id()));
    assert!(s.upload(abs.to_str().unwrap(), b"boom").is_err());
    assert!(!abs.exists(), "absolute key wrote outside the store root");
    fs::remove_dir_all(parent).ok();
}

// -- torn writes ---------------------------------------------------------------

#[test]
fn local_uploads_are_atomic_under_concurrent_reads() {
    // fs::write-in-place tears: a reader overlapping a rewrite can observe
    // a truncated or mixed object. temp-file + rename means every download
    // returns exactly one of the two full payloads.
    let dir = tmp("torn");
    let s = Arc::new(LocalStorage::new(&dir).unwrap());
    let a = vec![b'a'; 512 * 1024];
    let b = vec![b'b'; 512 * 1024];
    s.upload("k", &a).unwrap();
    let writer = {
        let s = Arc::clone(&s);
        let (a, b) = (a.clone(), b.clone());
        std::thread::spawn(move || {
            for i in 0..40 {
                s.upload("k", if i % 2 == 0 { &b } else { &a }).unwrap();
            }
        })
    };
    let check = |got: Vec<u8>| {
        assert_eq!(got.len(), a.len(), "torn (truncated) object observed");
        let first = got[0];
        assert!(first == b'a' || first == b'b');
        assert!(got.iter().all(|&x| x == first), "mixed (torn) object observed");
    };
    while !writer.is_finished() {
        check(s.download("k").unwrap());
    }
    writer.join().unwrap();
    check(s.download("k").unwrap());
    fs::remove_dir_all(dir).ok();
}

// -- md5 integrity -------------------------------------------------------------

#[test]
fn opctx_detects_ghost_md5_on_corrupted_object() {
    for (name, c) in clients("ghost") {
        let mut ctx = OpCtx::bare(Arc::clone(&c));
        let art = ctx.write_artifact("data", b"the real payload").unwrap();
        assert!(art.md5.is_some(), "{name}");
        // corrupt the object behind the ArtifactRef's back
        c.upload(&art.key, b"tampered bytes!!").unwrap();
        ctx.input_artifacts.insert("data".into(), art);
        let err = ctx.read_artifact("data").unwrap_err();
        assert!(err.is_transient(), "{name}: md5 mismatch must be transient: {err}");
        assert!(err.message().contains("md5 mismatch"), "{name}: {err}");
    }
}

// -- CAS dedup + zero-copy -----------------------------------------------------

#[test]
fn cas_dedup_uploading_same_bytes_twice_stores_one_chunk_set() {
    let mem = Arc::new(MemStorage::new());
    let cas = CasStore::new(mem.clone());
    let mut rng = Rng::new(42);
    let data: Vec<u8> = (0..2_500_000).map(|_| rng.next_u64() as u8).collect();
    cas.upload("first", &data).unwrap();
    let puts = cas.counters().chunk_puts.load(Ordering::Relaxed);
    let chunk_objects = mem.list(".cas/").unwrap().len();
    assert_eq!(puts as usize, chunk_objects);
    cas.upload("second", &data).unwrap();
    assert_eq!(
        cas.counters().chunk_puts.load(Ordering::Relaxed),
        puts,
        "identical upload must not store new chunks"
    );
    assert_eq!(mem.list(".cas/").unwrap().len(), chunk_objects);
    assert_eq!(
        cas.counters().dedup_bytes.load(Ordering::Relaxed),
        data.len() as u64,
        "the whole second upload must be dedup hits"
    );
    assert_eq!(cas.download("second").unwrap(), data);
}

#[test]
fn cas_copy_is_manifest_only_on_the_wire() {
    // over ObjectStoreSim every backing op is counted: a copy of a 3 MiB
    // object must cost O(manifest) ops and zero chunk transfers
    let sim = Arc::new(ObjectStoreSim::new(Duration::ZERO, 0.0, 7));
    let cas = CasStore::new(sim.clone());
    let mut rng = Rng::new(5);
    let data: Vec<u8> = (0..3_000_000).map(|_| rng.next_u64() as u8).collect();
    cas.upload("src", &data).unwrap();
    let ops_before = sim.ops.load(Ordering::Relaxed);
    let gets_before = cas.counters().chunk_gets.load(Ordering::Relaxed);
    let puts_before = cas.counters().chunk_puts.load(Ordering::Relaxed);
    cas.copy("src", "dst").unwrap();
    let ops_delta = sim.ops.load(Ordering::Relaxed) - ops_before;
    // read src manifest + probe dst + dirty-mark the refcount table +
    // write manifest + re-persist the table — still O(manifest), zero
    // chunk transfers
    assert!(ops_delta <= 5, "copy cost {ops_delta} backing ops; manifest-only means <= 5");
    assert_eq!(cas.counters().chunk_gets.load(Ordering::Relaxed), gets_before);
    assert_eq!(cas.counters().chunk_puts.load(Ordering::Relaxed), puts_before);
    assert_eq!(cas.download("dst").unwrap(), data);
}

#[test]
fn cas_get_md5_downloads_no_data() {
    let sim = Arc::new(ObjectStoreSim::new(Duration::ZERO, 0.0, 3));
    let cas = CasStore::new(sim.clone());
    let data = vec![9u8; 2_000_000];
    cas.upload("obj", &data).unwrap();
    let ops_before = sim.ops.load(Ordering::Relaxed);
    assert_eq!(cas.get_md5("obj").unwrap(), md5_hex(&data));
    assert_eq!(
        sim.ops.load(Ordering::Relaxed) - ops_before,
        1,
        "get_md5 must read exactly the manifest"
    );
    assert_eq!(cas.counters().chunk_gets.load(Ordering::Relaxed), 0);
}

// -- pack → CAS → unpack property ---------------------------------------------

#[test]
fn prop_pack_cas_roundtrip_any_directory() {
    check::forall("pack -> cas upload -> download -> unpack round-trips", |rng| {
        let src = tmp("prop-src");
        let nfiles = 1 + rng.below(5) as usize;
        for i in 0..nfiles {
            let rel = if rng.chance(0.4) {
                format!("{}/{}-{i}.bin", check::gen::ident(rng), check::gen::ident(rng))
            } else {
                format!("{}-{i}.bin", check::gen::ident(rng))
            };
            let full = src.join(&rel);
            fs::create_dir_all(full.parent().unwrap()).unwrap();
            let data: Vec<u8> = (0..rng.below(150_000)).map(|_| rng.next_u64() as u8).collect();
            fs::write(full, data).unwrap();
        }
        let archive = pack_dir(&src).unwrap();

        let cas = CasStore::new(Arc::new(MemStorage::new()));
        cas.upload("wf/artifact", &archive).unwrap();
        let fetched = cas.download("wf/artifact").unwrap();
        assert_eq!(fetched, archive, "bytes must survive the CAS round-trip");
        assert_eq!(cas.get_md5("wf/artifact").unwrap(), md5_hex(&archive));

        let dst = tmp("prop-dst");
        unpack_dir(&fetched, &dst).unwrap();
        // re-packing the unpacked tree reproduces the archive byte-for-byte
        // (pack_dir is deterministic: sorted relative paths)
        assert_eq!(pack_dir(&dst).unwrap(), archive, "directory content diverged");
        fs::remove_dir_all(src).ok();
        fs::remove_dir_all(dst).ok();
    });
}

// -- end-to-end: the engine's forwarding path is zero-copy ---------------------

#[test]
fn engine_artifact_forwarding_moves_zero_data_bytes_over_cas() {
    // a keyed sliced step writes a 256 KiB artifact per slice; the engine
    // stacks them (its copy_with_retry forwarding path). Over CAS the cold
    // run stores each artifact once, and the warm (full-reuse) run — whose
    // only artifact work is forwarding the unchanged artifacts into the new
    // run's stack — stores and fetches NOTHING.
    let sim = Arc::new(ObjectStoreSim::new(Duration::ZERO, 0.0, 11));
    let cas = Arc::new(CasStore::new(sim.clone() as Arc<dyn StorageClient>));
    // per-slice payload: 256 KiB of slice-seeded pseudo-random bytes, so
    // no two slices can share chunks and the put counters are exact
    fn payload(i: i64) -> Vec<u8> {
        let mut r = Rng::new(1000 + i as u64);
        (0..256 * 1024).map(|_| r.next_u64() as u8).collect()
    }
    let op = Arc::new(FnOp::new(
        Signature::new().in_param("i", ParamType::Int).out_artifact("blob"),
        |ctx| {
            let i = ctx.get_int("i")?;
            ctx.write_artifact("blob", &payload(i))?;
            Ok(())
        },
    ));
    let wf = Workflow::new("fwd")
        .container(ContainerTemplate::new("op", op))
        .steps(
            Steps::new("main")
                .then(
                    Step::new("fan", "op")
                        .param("i", Value::ints(0..4))
                        .slices(Slices::over("i").stack_artifact("blob").parallelism(2))
                        .key("fwd-{{item}}"),
                )
                .out_artifact_from("blobs", "fan", "blob"),
        )
        .entrypoint("main");

    let engine = Engine::builder().storage(cas.clone()).build();
    let cold = engine.run(&wf).unwrap();
    assert!(cold.succeeded(), "{:?}", cold.error);
    let cold_puts = cas.counters().chunk_puts.load(Ordering::Relaxed);
    let cold_put_bytes = cas.counters().chunk_put_bytes.load(Ordering::Relaxed);
    assert!(cold_puts >= 4, "each distinct slice artifact stores at least one chunk");
    assert!(cold_put_bytes >= 4 * 256 * 1024);
    // the engine's stacking copies moved no data even on the cold run:
    assert_eq!(
        cas.counters().chunk_gets.load(Ordering::Relaxed),
        0,
        "nothing should have downloaded chunk data"
    );

    // warm run: every slice reused; forwarding is the only artifact work
    let reuse = cold.run.all_keyed();
    assert_eq!(reuse.len(), 4);
    let warm = engine.run_with_reuse(&wf, reuse).unwrap();
    assert!(warm.succeeded(), "{:?}", warm.error);
    assert_eq!(warm.run.metrics.steps_reused.get(), 4);
    assert_eq!(
        cas.counters().chunk_puts.load(Ordering::Relaxed),
        cold_puts,
        "warm forwarding must not store any data bytes"
    );
    assert_eq!(
        cas.counters().chunk_put_bytes.load(Ordering::Relaxed),
        cold_put_bytes
    );
    assert_eq!(cas.counters().chunk_gets.load(Ordering::Relaxed), 0);

    // the forwarded stack is intact: slice 2's bytes come back verbatim
    let stacked = warm.outputs.artifacts.get("blobs").expect("stacked artifact");
    let slice2 = cas.download(&format!("{}/2", stacked.key)).unwrap();
    assert_eq!(slice2, payload(2));
}

// -- gc ------------------------------------------------------------------------

#[test]
fn cas_gc_reclaims_cancelled_attempt_orphans() {
    let mem = Arc::new(MemStorage::new());
    let cas = CasStore::new(mem.clone());
    let mut rng = Rng::new(77);
    let live: Vec<u8> = (0..1_500_000).map(|_| rng.next_u64() as u8).collect();
    let dead: Vec<u8> = (0..1_500_000).map(|_| rng.next_u64() as u8).collect();
    cas.upload("run9/step/a0/out", &live).unwrap();
    cas.upload("run9/step/a1/out", &dead).unwrap();
    // attempt a1 was cancelled: its namespace is dropped
    assert_eq!(cas.delete_prefix("run9/step/a1/").unwrap(), 1);
    // simulate a crashed upload too: a chunk body with no manifest
    mem.upload(".cas/ff/ffffffffffffffffffffffffffffffff", b"stray").unwrap();
    let report = cas.gc().unwrap();
    assert_eq!(report.manifests_scanned, 1);
    assert!(report.chunks_reclaimed >= 1, "stray chunk must be reclaimed");
    assert_eq!(cas.download("run9/step/a0/out").unwrap(), live);
    // after deleting the last manifest, gc leaves an empty store
    cas.delete("run9/step/a0/out").unwrap();
    cas.gc().unwrap();
    assert!(mem.is_empty(), "all chunks must be reclaimable");
}
